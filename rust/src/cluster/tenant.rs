//! Tenants and GPU quota management (§3.2.1 static quota admission).
//!
//! Quotas are per (tenant, GPU type) because heterogeneous models are not
//! comparable resources. Two modes:
//!
//! * **Isolated** — a tenant can never exceed its own limit.
//! * **Shared** — a tenant may *borrow* unused quota from other tenants;
//!   borrowing is recorded per job so quota-reclamation preemption (§3.2.3)
//!   can find exactly which jobs to evict when a lender wants capacity back.

use std::collections::BTreeMap;
use std::fmt;

use super::ids::{GpuTypeId, JobId, TenantId};

/// Quota sharing mode (cluster-wide policy in this implementation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuotaMode {
    Shared,
    Isolated,
}

/// A tenant of the cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct Tenant {
    pub id: TenantId,
    pub name: String,
    /// Weight for fair ordering across tenant queues (reserved for future
    /// fair-share work; 1.0 everywhere in the paper's experiments).
    pub weight: f64,
}

impl Tenant {
    pub fn new(id: TenantId, name: impl Into<String>) -> Tenant {
        Tenant {
            id,
            name: name.into(),
            weight: 1.0,
        }
    }
}

/// Per-(tenant, type) quota accounting entry. All units are GPU counts.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QuotaEntry {
    /// The tenant's own limit for this GPU type.
    pub limit: u32,
    /// GPUs in use charged against the tenant's own limit.
    pub used_own: u32,
    /// GPUs of this tenant's limit currently lent to other tenants.
    pub lent: u32,
    /// GPUs this tenant is currently borrowing from others.
    pub borrowed: u32,
}

impl QuotaEntry {
    /// Own headroom: quota not used by self and not lent away.
    pub fn own_free(&self) -> u32 {
        self.limit.saturating_sub(self.used_own + self.lent)
    }

    /// Total GPUs the tenant currently occupies of this type.
    pub fn occupied(&self) -> u32 {
        self.used_own + self.borrowed
    }
}

/// One borrowing record: `borrower` runs `job` on `amount` GPUs charged to
/// `lender`'s limit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BorrowRecord {
    pub job: JobId,
    pub gpu_type: GpuTypeId,
    pub borrower: TenantId,
    pub lender: TenantId,
    pub amount: u32,
}

/// Errors from quota operations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum QuotaError {
    OverQuota {
        tenant: TenantId,
        gpu_type: GpuTypeId,
        need: u32,
        available: u32,
    },
    AlreadyCharged(JobId),
    NotCharged(JobId),
}

impl fmt::Display for QuotaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            QuotaError::OverQuota { tenant, gpu_type, need, available } => write!(
                f,
                "tenant {tenant} over quota for type {gpu_type}: need {need}, available {available}"
            ),
            QuotaError::AlreadyCharged(j) => write!(f, "job {j} already charged"),
            QuotaError::NotCharged(j) => write!(f, "job {j} not charged"),
        }
    }
}

impl std::error::Error for QuotaError {}

/// The quota ledger: the static-quota half of QSCH admission.
#[derive(Debug, Clone)]
pub struct QuotaLedger {
    mode: QuotaMode,
    num_types: usize,
    /// Dense [tenant][type] entries.
    entries: Vec<QuotaEntry>,
    /// Active borrow records, by job (a job may borrow from several
    /// lenders). Ordered maps for defence in depth: point-lookup-only
    /// today, but a future traversal must be in stable id order.
    borrows: BTreeMap<JobId, Vec<BorrowRecord>>,
    /// Own-quota charges by job: (tenant, type, amount).
    charges: BTreeMap<JobId, Vec<(TenantId, GpuTypeId, u32)>>,
}

impl QuotaLedger {
    pub fn new(num_tenants: usize, num_types: usize, mode: QuotaMode) -> QuotaLedger {
        QuotaLedger {
            mode,
            num_types,
            entries: vec![QuotaEntry::default(); num_tenants * num_types],
            borrows: BTreeMap::new(),
            charges: BTreeMap::new(),
        }
    }

    pub fn mode(&self) -> QuotaMode {
        self.mode
    }

    #[inline]
    fn idx(&self, t: TenantId, g: GpuTypeId) -> usize {
        t.index() * self.num_types + g.index()
    }

    pub fn entry(&self, t: TenantId, g: GpuTypeId) -> QuotaEntry {
        self.entries[self.idx(t, g)]
    }

    pub fn set_limit(&mut self, t: TenantId, g: GpuTypeId, limit: u32) {
        let i = self.idx(t, g);
        self.entries[i].limit = limit;
    }

    fn num_tenants(&self) -> usize {
        self.entries.len() / self.num_types
    }

    /// Headroom available to `t` for a *new* request of type `g` under the
    /// current mode (does not mutate).
    pub fn available(&self, t: TenantId, g: GpuTypeId) -> u32 {
        let own = self.entry(t, g).own_free();
        match self.mode {
            QuotaMode::Isolated => own,
            QuotaMode::Shared => {
                let others: u32 = (0..self.num_tenants())
                    .filter(|&o| o != t.index())
                    .map(|o| self.entries[o * self.num_types + g.index()].own_free())
                    .sum();
                own + others
            }
        }
    }

    /// Static-quota admission check for one (type, amount) demand.
    pub fn admit_check(&self, t: TenantId, g: GpuTypeId, amount: u32) -> Result<(), QuotaError> {
        let available = self.available(t, g);
        if amount <= available {
            Ok(())
        } else {
            Err(QuotaError::OverQuota {
                tenant: t,
                gpu_type: g,
                need: amount,
                available,
            })
        }
    }

    /// Charge a job's demand against the ledger: own quota first, then (in
    /// Shared mode) borrow from lenders in descending headroom order.
    /// All-or-nothing: fails without mutating when headroom is insufficient.
    pub fn charge(
        &mut self,
        job: JobId,
        t: TenantId,
        demands: &[(GpuTypeId, u32)],
    ) -> Result<(), QuotaError> {
        if self.charges.contains_key(&job) || self.borrows.contains_key(&job) {
            return Err(QuotaError::AlreadyCharged(job));
        }
        for &(g, amount) in demands {
            self.admit_check(t, g, amount)?;
        }

        let mut charges = Vec::new();
        let mut borrows = Vec::new();
        for &(g, amount) in demands {
            let own = self.entry(t, g).own_free().min(amount);
            if own > 0 {
                let i = self.idx(t, g);
                self.entries[i].used_own += own;
                charges.push((t, g, own));
            }
            let mut rest = amount - own;
            if rest > 0 {
                debug_assert_eq!(self.mode, QuotaMode::Shared);
                // Borrow from lenders, largest headroom first (stable order
                // by tenant id for determinism).
                let mut lenders: Vec<(usize, u32)> = (0..self.num_tenants())
                    .filter(|&o| o != t.index())
                    .map(|o| (o, self.entries[o * self.num_types + g.index()].own_free()))
                    .filter(|&(_, free)| free > 0)
                    .collect();
                lenders.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
                for (o, free) in lenders {
                    if rest == 0 {
                        break;
                    }
                    let take = free.min(rest);
                    let oi = o * self.num_types + g.index();
                    self.entries[oi].lent += take;
                    let ti = self.idx(t, g);
                    self.entries[ti].borrowed += take;
                    borrows.push(BorrowRecord {
                        job,
                        gpu_type: g,
                        borrower: t,
                        lender: TenantId(o as u32),
                        amount: take,
                    });
                    rest -= take;
                }
                debug_assert_eq!(rest, 0, "admit_check guaranteed headroom");
            }
        }
        if !charges.is_empty() {
            self.charges.insert(job, charges);
        }
        if !borrows.is_empty() {
            self.borrows.insert(job, borrows);
        }
        Ok(())
    }

    /// Return a job's quota (on completion, preemption or requeue).
    pub fn refund(&mut self, job: JobId) -> Result<(), QuotaError> {
        let charges = self.charges.remove(&job);
        let borrows = self.borrows.remove(&job);
        if charges.is_none() && borrows.is_none() {
            return Err(QuotaError::NotCharged(job));
        }
        for (t, g, amount) in charges.unwrap_or_default() {
            let i = self.idx(t, g);
            self.entries[i].used_own -= amount;
        }
        for b in borrows.unwrap_or_default() {
            let li = self.idx(b.lender, b.gpu_type);
            self.entries[li].lent -= b.amount;
            let bi = self.idx(b.borrower, b.gpu_type);
            self.entries[bi].borrowed -= b.amount;
        }
        Ok(())
    }

    /// Jobs currently borrowing from `lender` on type `g`, largest borrow
    /// first — the candidate list for quota-reclamation preemption.
    pub fn debtors(&self, lender: TenantId, g: GpuTypeId) -> Vec<BorrowRecord> {
        let mut out: Vec<BorrowRecord> = self
            .borrows
            .values()
            .flatten()
            .filter(|b| b.lender == lender && b.gpu_type == g)
            .copied()
            .collect();
        out.sort_by(|a, b| b.amount.cmp(&a.amount).then(a.job.cmp(&b.job)));
        out
    }

    /// Whether `job` runs (partly) on borrowed quota.
    pub fn is_borrowing(&self, job: JobId) -> bool {
        self.borrows.contains_key(&job)
    }

    /// Quota utilization (occupied / limit) per tenant for type `g` —
    /// Figure 10's series.
    pub fn utilization(&self, g: GpuTypeId) -> Vec<(TenantId, u32, u32)> {
        (0..self.num_tenants())
            .map(|t| {
                let e = self.entries[t * self.num_types + g.index()];
                (TenantId(t as u32), e.limit, e.occupied())
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const T0: TenantId = TenantId(0);
    const T1: TenantId = TenantId(1);
    const T2: TenantId = TenantId(2);
    const G: GpuTypeId = GpuTypeId(0);

    fn ledger(mode: QuotaMode) -> QuotaLedger {
        let mut l = QuotaLedger::new(3, 1, mode);
        l.set_limit(T0, G, 8);
        l.set_limit(T1, G, 16);
        l.set_limit(T2, G, 0);
        l
    }

    #[test]
    fn isolated_enforces_own_limit() {
        let mut l = ledger(QuotaMode::Isolated);
        assert_eq!(l.available(T0, G), 8);
        l.charge(JobId(1), T0, &[(G, 8)]).unwrap();
        assert!(matches!(
            l.charge(JobId(2), T0, &[(G, 1)]),
            Err(QuotaError::OverQuota { available: 0, .. })
        ));
    }

    #[test]
    fn shared_allows_borrowing() {
        let mut l = ledger(QuotaMode::Shared);
        assert_eq!(l.available(T0, G), 24);
        l.charge(JobId(1), T0, &[(G, 20)]).unwrap();
        let e0 = l.entry(T0, G);
        assert_eq!(e0.used_own, 8);
        assert_eq!(e0.borrowed, 12);
        assert_eq!(l.entry(T1, G).lent, 12);
        assert!(l.is_borrowing(JobId(1)));
    }

    #[test]
    fn shared_still_bounded_by_total() {
        let mut l = ledger(QuotaMode::Shared);
        assert!(l.charge(JobId(1), T0, &[(G, 25)]).is_err());
    }

    #[test]
    fn refund_restores_everything() {
        let mut l = ledger(QuotaMode::Shared);
        l.charge(JobId(1), T0, &[(G, 20)]).unwrap();
        l.refund(JobId(1)).unwrap();
        assert_eq!(l.entry(T0, G), QuotaEntry { limit: 8, ..Default::default() });
        assert_eq!(l.entry(T1, G).lent, 0);
        assert_eq!(l.available(T0, G), 24);
    }

    #[test]
    fn refund_unknown_job_errors() {
        let mut l = ledger(QuotaMode::Shared);
        assert!(matches!(l.refund(JobId(99)), Err(QuotaError::NotCharged(_))));
    }

    #[test]
    fn double_charge_rejected() {
        let mut l = ledger(QuotaMode::Shared);
        l.charge(JobId(1), T0, &[(G, 2)]).unwrap();
        assert!(matches!(
            l.charge(JobId(1), T0, &[(G, 2)]),
            Err(QuotaError::AlreadyCharged(_))
        ));
    }

    #[test]
    fn debtors_lists_borrowers_of_lender() {
        let mut l = ledger(QuotaMode::Shared);
        l.charge(JobId(1), T0, &[(G, 12)]).unwrap(); // borrows 4 from T1
        l.charge(JobId(2), T2, &[(G, 6)]).unwrap(); // borrows 6 from T1
        let debts = l.debtors(T1, G);
        assert_eq!(debts.len(), 2);
        assert_eq!(debts[0].job, JobId(2)); // Largest borrow first.
        assert_eq!(debts[0].amount, 6);
        assert_eq!(debts[1].amount, 4);
    }

    #[test]
    fn lender_own_free_shrinks_while_lent() {
        let mut l = ledger(QuotaMode::Shared);
        l.charge(JobId(1), T0, &[(G, 12)]).unwrap(); // T1 lends 4
        assert_eq!(l.entry(T1, G).own_free(), 12);
        // T1 can still use its remaining 12 itself.
        l.charge(JobId(2), T1, &[(G, 12)]).unwrap();
        assert_eq!(l.available(T1, G), 0);
    }

    #[test]
    fn multi_type_demand_charges_each_type() {
        let mut l = QuotaLedger::new(2, 2, QuotaMode::Isolated);
        let g0 = GpuTypeId(0);
        let g1 = GpuTypeId(1);
        l.set_limit(T0, g0, 4);
        l.set_limit(T0, g1, 2);
        l.charge(JobId(1), T0, &[(g0, 4), (g1, 2)]).unwrap();
        assert_eq!(l.entry(T0, g0).used_own, 4);
        assert_eq!(l.entry(T0, g1).used_own, 2);
        // Insufficient on one type → nothing charged at all.
        l.refund(JobId(1)).unwrap();
        assert!(l.charge(JobId(2), T0, &[(g0, 1), (g1, 3)]).is_err());
        assert_eq!(l.entry(T0, g0).used_own, 0);
    }

    #[test]
    fn utilization_reports_all_tenants() {
        let mut l = ledger(QuotaMode::Shared);
        l.charge(JobId(1), T0, &[(G, 4)]).unwrap();
        let u = l.utilization(G);
        assert_eq!(u.len(), 3);
        assert_eq!(u[0], (T0, 8, 4));
        assert_eq!(u[1], (T1, 16, 0));
    }
}
