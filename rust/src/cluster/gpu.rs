//! GPU models, devices and NICs — the device-level resources RSCH's
//! fine-grained scheduling (§3.3.1) assigns to pods.

use super::ids::{GpuTypeId, PodId};

/// A GPU model. Clusters are split into GPU-Type-based node pools (§3.4.1)
/// because models are not interchangeable: quota, admission and scheduling
/// all operate per type.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuType {
    pub id: GpuTypeId,
    pub name: String,
    /// Peak bf16 TFLOPs — used only for reporting, never for placement.
    pub tflops: f64,
    pub mem_gb: u32,
    /// Intra-node NVLink islands: groups of GPU indices that are
    /// all-to-all NVLink-connected. One island of 8 models an H100-class
    /// board; two islands of 4 model a PCIe-bridged pair of quads.
    pub nvlink_islands: Vec<Vec<u8>>,
    /// GPUs per node for this model.
    pub gpus_per_node: u8,
    /// NICs per node and the GPUs each NIC serves (topology pairing).
    pub nics_per_node: u8,
}

impl GpuType {
    /// Standard 8-GPU fully-NVLinked training board (Type-H in figures).
    pub fn type_h(id: GpuTypeId) -> GpuType {
        GpuType {
            id,
            name: "Type-H".to_string(),
            tflops: 989.0,
            mem_gb: 80,
            nvlink_islands: vec![(0..8).collect()],
            gpus_per_node: 8,
            nics_per_node: 4,
        }
    }

    /// 8-GPU board split into two PCIe-bridged NVLink quads (Type-L).
    pub fn type_l(id: GpuTypeId) -> GpuType {
        GpuType {
            id,
            name: "Type-L".to_string(),
            tflops: 362.0,
            mem_gb: 48,
            nvlink_islands: vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]],
            gpus_per_node: 8,
            nics_per_node: 2,
        }
    }

    /// Inference-oriented 4-GPU PCIe board (Type-A).
    pub fn type_a(id: GpuTypeId) -> GpuType {
        GpuType {
            id,
            name: "Type-A".to_string(),
            tflops: 165.0,
            mem_gb: 24,
            nvlink_islands: vec![vec![0], vec![1], vec![2], vec![3]],
            gpus_per_node: 4,
            nics_per_node: 1,
        }
    }

    /// The NVLink island containing GPU `idx`, if any.
    pub fn island_of(&self, idx: u8) -> Option<&[u8]> {
        self.nvlink_islands
            .iter()
            .find(|island| island.contains(&idx))
            .map(|v| v.as_slice())
    }

    /// Which NIC index serves GPU `idx`: GPUs are striped across NICs in
    /// contiguous blocks (GPUs 0..k → NIC 0, etc.).
    pub fn nic_for_gpu(&self, idx: u8) -> u8 {
        let per_nic = (self.gpus_per_node / self.nics_per_node).max(1);
        (idx / per_nic).min(self.nics_per_node - 1)
    }
}

/// Health of a device or node — the reliability lifecycle
/// `Healthy → Cordoned/Draining → Faulty → Repairing → Healthy`.
///
/// Only `Healthy` units accept new placements; every other state is
/// excluded from the free-capacity aggregates, the snapshot's `healthy`
/// flag, the `NodeIndex` buckets, and the GFR denominator alike.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    Healthy,
    /// Administratively unschedulable (hot spares, manual holds); still
    /// counted in totals. Residents, if any, keep running.
    Cordoned,
    /// Being emptied for maintenance: no new placements, residents keep
    /// running, and defragmentation rounds migrate them away
    /// (drain-aware scheduling — see `rsch::defrag`).
    Draining,
    /// Hardware-failed; residents are evicted (§3.2.4 requeue). The
    /// simulator's fault injector detects failures instantly, so a unit
    /// transitions on to `Repairing` within the same fault event.
    Faulty,
    /// A failed unit waiting out its MTTR before returning to service.
    Repairing,
}

impl Health {
    #[inline]
    pub fn schedulable(self) -> bool {
        matches!(self, Health::Healthy)
    }
}

/// One physical GPU device on a node.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuDevice {
    /// Index on the node board (0..gpus_per_node).
    pub index: u8,
    pub health: Health,
    /// The pod currently bound to this device (non-shared allocation mode;
    /// the paper notes GPUs are typically allocated whole).
    pub allocated_to: Option<PodId>,
}

impl GpuDevice {
    pub fn new(index: u8) -> GpuDevice {
        GpuDevice {
            index,
            health: Health::Healthy,
            allocated_to: None,
        }
    }

    #[inline]
    pub fn free(&self) -> bool {
        self.allocated_to.is_none() && self.health.schedulable()
    }
}

/// One RDMA NIC on a node. Pods are paired with the NIC topologically
/// closest to their GPUs (§3.3.1, §3.3.5 intra-node).
#[derive(Debug, Clone, PartialEq)]
pub struct Nic {
    pub index: u8,
    pub health: Health,
}

impl Nic {
    pub fn new(index: u8) -> Nic {
        Nic {
            index,
            health: Health::Healthy,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ids::JobId;

    #[test]
    fn type_h_is_one_full_island() {
        let t = GpuType::type_h(GpuTypeId(0));
        assert_eq!(t.nvlink_islands.len(), 1);
        assert_eq!(t.island_of(5).unwrap().len(), 8);
    }

    #[test]
    fn type_l_has_two_quads() {
        let t = GpuType::type_l(GpuTypeId(0));
        assert_eq!(t.island_of(2).unwrap(), &[0, 1, 2, 3]);
        assert_eq!(t.island_of(6).unwrap(), &[4, 5, 6, 7]);
    }

    #[test]
    fn nic_pairing_stripes_gpus() {
        let t = GpuType::type_h(GpuTypeId(0)); // 8 GPUs, 4 NICs → 2 GPUs per NIC
        assert_eq!(t.nic_for_gpu(0), 0);
        assert_eq!(t.nic_for_gpu(1), 0);
        assert_eq!(t.nic_for_gpu(2), 1);
        assert_eq!(t.nic_for_gpu(7), 3);
    }

    #[test]
    fn device_free_accounts_health_and_allocation() {
        let mut d = GpuDevice::new(0);
        assert!(d.free());
        d.health = Health::Faulty;
        assert!(!d.free());
        d.health = Health::Healthy;
        d.allocated_to = Some(PodId::new(JobId(1), 0));
        assert!(!d.free());
    }
}
