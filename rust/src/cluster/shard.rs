//! Superspine shard map: the structural partition behind the sharded
//! scheduler core.
//!
//! Shards are *not* a tunable — one shard per superspine, fixed by the
//! fabric (`Tier::CrossSuperSpine` is the natural cut: most gangs fit
//! inside one superspine, so shard-local planning sees the whole
//! topology a gang's score depends on). The `--shards N` knob only
//! chooses how many worker threads sweep the fixed shards; because the
//! structure and the shard→work assignment are derived from topology
//! and shard ids alone, results are byte-identical for any thread count.

use super::ids::GroupId;
use super::state::ClusterState;

/// Immutable partition of a cluster's LeafGroups by superspine.
#[derive(Debug, Clone)]
pub struct ShardMap {
    /// Group index → shard (= superspine) index.
    shard_of_group: Vec<u32>,
    /// Shard → pool → that pool's groups inside the shard, in the same
    /// (sorted) order `ClusterState::pool_groups` yields them, so a
    /// shard-local group walk visits groups in the exact relative order
    /// the unsharded planner would.
    pool_groups: Vec<Vec<Vec<GroupId>>>,
}

impl ShardMap {
    pub fn new(state: &ClusterState) -> ShardMap {
        let num_shards = state.fabric.num_superspines.max(1) as usize;
        let mut shard_of_group = vec![0u32; state.fabric.num_groups()];
        for g in &state.fabric.groups {
            let ss = state.fabric.spines[g.spine.index()].superspine;
            shard_of_group[g.id.index()] = ss.index() as u32;
        }
        let per_pool = state.pool_groups();
        let mut pool_groups = vec![vec![Vec::new(); per_pool.len()]; num_shards];
        for (pool, groups) in per_pool.iter().enumerate() {
            for &g in groups {
                let shard = shard_of_group[g.index()] as usize;
                pool_groups[shard][pool].push(g);
            }
        }
        ShardMap {
            shard_of_group,
            pool_groups,
        }
    }

    #[inline]
    pub fn num_shards(&self) -> usize {
        self.pool_groups.len()
    }

    #[inline]
    pub fn shard_of_group(&self, g: GroupId) -> usize {
        self.shard_of_group[g.index()] as usize
    }

    /// The shard's groups, per pool (pool index → sorted group list).
    #[inline]
    pub fn pool_groups(&self, shard: usize) -> &[Vec<GroupId>] {
        &self.pool_groups[shard]
    }

    /// Current free GPUs per pool inside `shard` (the shard-routing
    /// feasibility signal — cheap: sums the state's per-group counters).
    pub fn free_by_pool(&self, state: &ClusterState, shard: usize) -> Vec<u32> {
        self.pool_groups[shard]
            .iter()
            .map(|groups| groups.iter().map(|&g| state.group_free(g)).sum())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::builder::{ClusterBuilder, ClusterSpec};

    #[test]
    fn shards_partition_groups_by_superspine() {
        // 4 spines × 1 group × 32 nodes, 2 spines per superspine → 2 shards
        // of 2 groups each (the Small training preset's shape).
        let mut spec = ClusterSpec::homogeneous("t", 4, 1, 32);
        spec.spines_per_superspine = 2;
        let state = ClusterBuilder::build(&spec);
        let shards = ShardMap::new(&state);
        assert_eq!(shards.num_shards(), 2);
        assert_eq!(shards.shard_of_group(GroupId(0)), 0);
        assert_eq!(shards.shard_of_group(GroupId(1)), 0);
        assert_eq!(shards.shard_of_group(GroupId(2)), 1);
        assert_eq!(shards.shard_of_group(GroupId(3)), 1);
        // Every pool group lands in exactly one shard, order preserved.
        let total: usize = (0..shards.num_shards())
            .map(|s| shards.pool_groups(s)[0].len())
            .sum();
        assert_eq!(total, state.fabric.num_groups());
        assert_eq!(shards.pool_groups(1)[0], vec![GroupId(2), GroupId(3)]);
    }

    #[test]
    fn free_by_pool_tracks_group_counters() {
        let mut spec = ClusterSpec::homogeneous("t", 4, 1, 4);
        spec.spines_per_superspine = 2;
        let state = ClusterBuilder::build(&spec);
        let shards = ShardMap::new(&state);
        // 2 groups × 4 nodes × 8 GPUs per shard, all free.
        assert_eq!(shards.free_by_pool(&state, 0), vec![64]);
        assert_eq!(shards.free_by_pool(&state, 1), vec![64]);
    }

    // 12,500-node build: skipped under Miri (interpreter cost, no
    // unsafe surface) — the 4-spine cases above cover the partition.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn hundred_thousand_gpu_preset_has_ten_shards() {
        let state = ClusterBuilder::build(&ClusterSpec::train100000());
        let shards = ShardMap::new(&state);
        assert_eq!(shards.num_shards(), 10);
        let per_shard: Vec<u32> = (0..10)
            .map(|s| shards.free_by_pool(&state, s)[0])
            .collect();
        assert!(per_shard.iter().all(|&f| f == 10_000));
    }
}
