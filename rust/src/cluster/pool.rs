//! GPU-Type-based node pools (§3.4.1): heterogeneous clusters are split by
//! GPU model so scheduling searches only within the matching pool instead of
//! traversing the whole cluster.

use super::ids::{GpuTypeId, NodeId, PoolId};

/// One node pool: all nodes carrying a given GPU model.
#[derive(Debug, Clone, PartialEq)]
pub struct NodePool {
    pub id: PoolId,
    pub gpu_type: GpuTypeId,
    pub nodes: Vec<NodeId>,
    /// Total GPUs across member nodes (static).
    pub total_gpus: u32,
}

impl NodePool {
    pub fn new(id: PoolId, gpu_type: GpuTypeId) -> NodePool {
        NodePool {
            id,
            gpu_type,
            nodes: Vec::new(),
            total_gpus: 0,
        }
    }

    pub fn add_node(&mut self, node: NodeId, gpus: u32) {
        self.nodes.push(node);
        self.total_gpus += gpus;
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }
}

/// Pool registry with type→pool lookup.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PoolSet {
    pools: Vec<NodePool>,
}

impl PoolSet {
    pub fn new() -> PoolSet {
        PoolSet::default()
    }

    /// Get or create the pool for `gpu_type`.
    pub fn pool_for_type_mut(&mut self, gpu_type: GpuTypeId) -> &mut NodePool {
        if let Some(i) = self.pools.iter().position(|p| p.gpu_type == gpu_type) {
            &mut self.pools[i]
        } else {
            let id = PoolId(self.pools.len() as u16);
            self.pools.push(NodePool::new(id, gpu_type));
            self.pools.last_mut().unwrap()
        }
    }

    pub fn pool_for_type(&self, gpu_type: GpuTypeId) -> Option<&NodePool> {
        self.pools.iter().find(|p| p.gpu_type == gpu_type)
    }

    pub fn get(&self, id: PoolId) -> &NodePool {
        &self.pools[id.index()]
    }

    pub fn iter(&self) -> impl Iterator<Item = &NodePool> {
        self.pools.iter()
    }

    pub fn len(&self) -> usize {
        self.pools.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pools.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pools_partition_by_type() {
        let mut ps = PoolSet::new();
        ps.pool_for_type_mut(GpuTypeId(0)).add_node(NodeId(0), 8);
        ps.pool_for_type_mut(GpuTypeId(1)).add_node(NodeId(1), 4);
        ps.pool_for_type_mut(GpuTypeId(0)).add_node(NodeId(2), 8);
        assert_eq!(ps.len(), 2);
        let p0 = ps.pool_for_type(GpuTypeId(0)).unwrap();
        assert_eq!(p0.num_nodes(), 2);
        assert_eq!(p0.total_gpus, 16);
        let p1 = ps.pool_for_type(GpuTypeId(1)).unwrap();
        assert_eq!(p1.total_gpus, 4);
    }

    #[test]
    fn missing_type_is_none() {
        let ps = PoolSet::new();
        assert!(ps.pool_for_type(GpuTypeId(9)).is_none());
    }

    #[test]
    fn pool_ids_are_stable() {
        let mut ps = PoolSet::new();
        let id0 = ps.pool_for_type_mut(GpuTypeId(5)).id;
        let id1 = ps.pool_for_type_mut(GpuTypeId(6)).id;
        assert_eq!(ps.get(id0).gpu_type, GpuTypeId(5));
        assert_eq!(ps.get(id1).gpu_type, GpuTypeId(6));
    }
}
