//! Incremental free-capacity node index: sublinear candidate selection
//! for RSCH's per-pod hot path.
//!
//! Kant's headline claim is stable scheduling "in clusters ranging from
//! hundreds to tens of thousands of GPUs"; the §3.4 mechanisms (GPU-type
//! pools, two-level NodeNetGroup scheduling, incremental snapshots) all
//! exist to keep per-cycle work from scaling with cluster size. This
//! module closes the remaining O(pool) scan in candidate filtering:
//! schedulable nodes are bucketed by **(NodeNetGroup, zone class,
//! free-GPU count)**, so selecting candidates for a pod needing `g` GPUs
//! walks only the buckets with `free >= g` instead of every node in the
//! pool. Whole-node placements (`g` = board size) degenerate to reading
//! the single whole-node-free bucket directly — exactly the set E-Spread's
//! fallback and large-gang E-Binpack care about.
//!
//! The index is maintained **incrementally from the same mutation log
//! that feeds [`Snapshot::refresh`]**: a full rebuild on the first
//! refresh (or after log compaction), then one [`NodeIndex::update_record`]
//! per touched node. It therefore always mirrors the *snapshot's* view —
//! the consistent scheduling-time state — never a half-applied one.
//!
//! Correctness contract: for any `(group, min_free, zone)` query the
//! index returns exactly the nodes whose **snapshot record** satisfies
//! `healthy && free >= min_free && zone matches`, in ascending [`NodeId`]
//! order. Callers re-apply plan-local conditions (in-flight device
//! takings, HBD pinning) on this superset, which is what makes indexed
//! selection produce placements byte-identical to the linear scan — a
//! property-tested invariant (`tests/prop_invariants.rs`).
//!
//! [`Snapshot::refresh`]: super::snapshot::Snapshot::refresh

use super::ids::{GroupId, NodeId};
use super::snapshot::NodeRecord;
use super::state::ClusterState;

/// Zone-class predicate for queries (mirrors RSCH's E-Spread phases).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ZoneQuery {
    /// Both zone classes.
    Any,
    /// Only nodes inside the inference dedicated zone.
    ZoneOnly,
    /// Only general-pool nodes.
    GeneralOnly,
}

/// The slice of one node's state the index buckets on.
#[derive(Debug, Clone, Copy)]
pub struct IndexEntry {
    pub id: NodeId,
    pub group: GroupId,
    pub free: u32,
    pub total: u32,
    pub zoned: bool,
    pub healthy: bool,
}

impl IndexEntry {
    fn of_record(r: &NodeRecord) -> IndexEntry {
        IndexEntry {
            id: r.id,
            group: r.group,
            free: r.free,
            total: r.total,
            zoned: r.in_inference_zone,
            healthy: r.healthy,
        }
    }
}

/// Where one node currently sits (for O(log bucket) removal on update).
#[derive(Debug, Clone, Copy, Default)]
struct Slot {
    free: u32,
    zoned: bool,
    present: bool,
}

/// Free-count buckets of one NodeNetGroup, split by zone class
/// (`[0]` = general pool, `[1]` = inference dedicated zone). Bucket `f`
/// holds the schedulable member nodes with exactly `f` free GPUs, each
/// bucket sorted ascending by node id.
#[derive(Debug, Clone, Default)]
struct GroupBuckets {
    by_free: [Vec<Vec<NodeId>>; 2],
}

/// The free-capacity index. See the module docs for the contract.
#[derive(Debug, Clone, Default)]
pub struct NodeIndex {
    groups: Vec<GroupBuckets>,
    slots: Vec<Slot>,
}

fn zone_idx(zoned: bool) -> usize {
    usize::from(zoned)
}

impl NodeIndex {
    /// Build from a snapshot's node records (full-rebuild path).
    pub fn from_records(records: &[NodeRecord], num_groups: usize) -> NodeIndex {
        Self::build(records.iter().map(IndexEntry::of_record), num_groups, records.len())
    }

    /// Build directly from the authoritative state (used by consumers that
    /// run outside the snapshot cycle, e.g. defragmentation rounds).
    pub fn from_state(state: &ClusterState) -> NodeIndex {
        let entries = state.nodes.iter().map(|n| IndexEntry {
            id: n.id,
            group: n.group,
            free: n.free_gpus(),
            total: n.total_gpus(),
            zoned: n.zone == super::node::Zone::InferenceDedicated,
            healthy: n.health.schedulable(),
        });
        Self::build(entries, state.fabric.num_groups(), state.nodes.len())
    }

    fn build(
        entries: impl Iterator<Item = IndexEntry> + Clone,
        num_groups: usize,
        num_nodes: usize,
    ) -> NodeIndex {
        let mut caps = vec![0u32; num_groups];
        for e in entries.clone() {
            let c = &mut caps[e.group.index()];
            *c = (*c).max(e.total);
        }
        let mut ix = NodeIndex {
            groups: caps
                .iter()
                .map(|&c| GroupBuckets {
                    by_free: [
                        vec![Vec::new(); c as usize + 1],
                        vec![Vec::new(); c as usize + 1],
                    ],
                })
                .collect(),
            slots: vec![Slot::default(); num_nodes],
        };
        for e in entries {
            ix.insert(&e);
        }
        ix
    }

    fn insert(&mut self, e: &IndexEntry) {
        self.slots[e.id.index()] = Slot {
            free: e.free,
            zoned: e.zoned,
            present: e.healthy,
        };
        if e.healthy {
            let b = &mut self.groups[e.group.index()].by_free[zone_idx(e.zoned)][e.free as usize];
            let pos = b.partition_point(|&n| n < e.id);
            b.insert(pos, e.id);
        }
    }

    /// Re-slot one node after its snapshot record changed (the incremental
    /// path, driven by the cluster's mutation log).
    pub fn update_record(&mut self, rec: &NodeRecord) {
        let e = IndexEntry::of_record(rec);
        let old = self.slots[e.id.index()];
        if old.present {
            let b =
                &mut self.groups[e.group.index()].by_free[zone_idx(old.zoned)][old.free as usize];
            if let Ok(pos) = b.binary_search(&e.id) {
                b.remove(pos);
            }
        }
        self.insert(&e);
    }

    /// Append every indexed node of `group` with `min_free <= free <=
    /// max_free` and a matching zone class to `out`. Returns how many
    /// nodes were walked (== appended) — the work counter the §3.4
    /// ablation reports. Each bucket is ascending by id; callers merging
    /// several buckets/groups sort once at the end.
    pub fn for_group_range(
        &self,
        group: GroupId,
        min_free: u32,
        max_free: u32,
        zone: ZoneQuery,
        out: &mut Vec<NodeId>,
    ) -> u64 {
        let Some(gb) = self.groups.get(group.index()) else {
            return 0;
        };
        let mut walked = 0u64;
        for (zi, buckets) in gb.by_free.iter().enumerate() {
            let keep = match zone {
                ZoneQuery::Any => true,
                ZoneQuery::ZoneOnly => zi == 1,
                ZoneQuery::GeneralOnly => zi == 0,
            };
            if !keep || buckets.is_empty() {
                continue;
            }
            let lo = min_free as usize;
            let hi = (max_free as usize).min(buckets.len() - 1);
            if lo > hi {
                continue;
            }
            for b in &buckets[lo..=hi] {
                walked += b.len() as u64;
                out.extend_from_slice(b);
            }
        }
        walked
    }

    /// [`for_group_range`](Self::for_group_range) with no upper bound.
    pub fn for_group(
        &self,
        group: GroupId,
        min_free: u32,
        zone: ZoneQuery,
        out: &mut Vec<NodeId>,
    ) -> u64 {
        self.for_group_range(group, min_free, u32::MAX, zone, out)
    }

    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::builder::{ClusterBuilder, ClusterSpec};
    use crate::cluster::gpu::Health;
    use crate::cluster::ids::{JobId, PodId};
    use crate::cluster::snapshot::{Snapshot, SnapshotMode};
    use crate::cluster::state::PodPlacement;
    use crate::prop_assert;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    fn state() -> ClusterState {
        // 2 spines x 2 groups x 4 nodes x 8 GPUs = 16 nodes.
        ClusterBuilder::build(&ClusterSpec::homogeneous("ix", 2, 2, 4))
    }

    fn placement(job: u64, node: u32, devs: Vec<u8>) -> PodPlacement {
        PodPlacement {
            pod: PodId::new(JobId(job), 0),
            node: NodeId(node),
            devices: devs,
            nic: 0,
        }
    }

    /// Reference query: linear scan over the snapshot records.
    fn brute(
        snap: &Snapshot,
        group: GroupId,
        min_free: u32,
        max_free: u32,
        zone: ZoneQuery,
    ) -> Vec<NodeId> {
        snap.nodes
            .iter()
            .filter(|r| {
                r.group == group
                    && r.healthy
                    && r.free >= min_free
                    && r.free <= max_free
                    && match zone {
                        ZoneQuery::Any => true,
                        ZoneQuery::ZoneOnly => r.in_inference_zone,
                        ZoneQuery::GeneralOnly => !r.in_inference_zone,
                    }
            })
            .map(|r| r.id)
            .collect()
    }

    fn query(ix: &NodeIndex, group: GroupId, min: u32, max: u32, zone: ZoneQuery) -> Vec<NodeId> {
        let mut out = Vec::new();
        ix.for_group_range(group, min, max, zone, &mut out);
        out.sort_unstable();
        out
    }

    #[test]
    fn fresh_cluster_is_all_whole_free() {
        let s = state();
        let mut snap = Snapshot::with_index(SnapshotMode::DeepCopy, true);
        snap.refresh(&s);
        let ix = snap.index().unwrap();
        // Every node sits in the free==8 bucket; asking for whole nodes
        // walks exactly the group's node count and nothing else.
        let mut out = Vec::new();
        let walked = ix.for_group(GroupId(0), 8, ZoneQuery::Any, &mut out);
        assert_eq!(walked, 4);
        assert_eq!(out, (0..4).map(NodeId).collect::<Vec<_>>());
        // And a 1-GPU query walks the same nodes (no emptier buckets).
        let mut out1 = Vec::new();
        assert_eq!(ix.for_group(GroupId(0), 1, ZoneQuery::Any, &mut out1), 4);
    }

    #[test]
    fn allocations_move_nodes_between_buckets() {
        let mut s = state();
        let mut snap = Snapshot::with_index(SnapshotMode::Incremental, true);
        snap.refresh(&s);
        s.commit_placements(JobId(1), vec![placement(1, 0, vec![0, 1, 2])])
            .unwrap();
        snap.refresh(&s);
        let ix = snap.index().unwrap();
        // Node 0 now has 5 free: excluded from a 6-GPU query, included in 5.
        assert_eq!(
            query(ix, GroupId(0), 6, u32::MAX, ZoneQuery::Any),
            (1..4).map(NodeId).collect::<Vec<_>>()
        );
        assert!(query(ix, GroupId(0), 5, u32::MAX, ZoneQuery::Any).contains(&NodeId(0)));
        // Whole-free count in the group dropped to 3.
        assert_eq!(query(ix, GroupId(0), 8, u32::MAX, ZoneQuery::Any).len(), 3);
    }

    #[test]
    fn unhealthy_nodes_leave_the_index() {
        let mut s = state();
        let mut snap = Snapshot::with_index(SnapshotMode::Incremental, true);
        snap.refresh(&s);
        s.set_node_health(NodeId(2), Health::Cordoned);
        snap.refresh(&s);
        let ix = snap.index().unwrap();
        let all = query(ix, GroupId(0), 0, u32::MAX, ZoneQuery::Any);
        assert!(!all.contains(&NodeId(2)));
        s.set_node_health(NodeId(2), Health::Healthy);
        snap.refresh(&s);
        let healed = query(snap.index().unwrap(), GroupId(0), 8, u32::MAX, ZoneQuery::Any);
        assert!(healed.contains(&NodeId(2)));
    }

    #[test]
    fn zone_classes_are_disjoint() {
        let mut spec = ClusterSpec::homogeneous("z", 1, 4, 4);
        spec.inference_zone_frac = 0.25; // Group 3 zoned.
        let s = ClusterBuilder::build(&spec);
        let mut snap = Snapshot::with_index(SnapshotMode::DeepCopy, true);
        snap.refresh(&s);
        let ix = snap.index().unwrap();
        assert!(query(ix, GroupId(3), 1, u32::MAX, ZoneQuery::GeneralOnly).is_empty());
        assert_eq!(query(ix, GroupId(3), 1, u32::MAX, ZoneQuery::ZoneOnly).len(), 4);
        assert!(query(ix, GroupId(0), 1, u32::MAX, ZoneQuery::ZoneOnly).is_empty());
    }

    #[test]
    fn from_state_matches_snapshot_built_index() {
        let mut s = state();
        s.commit_placements(JobId(1), vec![placement(1, 5, vec![0, 1])])
            .unwrap();
        s.set_node_health(NodeId(9), Health::Cordoned);
        let mut snap = Snapshot::with_index(SnapshotMode::DeepCopy, true);
        snap.refresh(&s);
        let from_state = NodeIndex::from_state(&s);
        let from_snap = snap.index().unwrap();
        for g in 0..s.fabric.num_groups() {
            for min in [0u32, 1, 4, 8] {
                for zone in [ZoneQuery::Any, ZoneQuery::ZoneOnly, ZoneQuery::GeneralOnly] {
                    assert_eq!(
                        query(&from_state, GroupId(g as u32), min, u32::MAX, zone),
                        query(from_snap, GroupId(g as u32), min, u32::MAX, zone),
                    );
                }
            }
        }
    }

    #[test]
    fn property_incremental_index_matches_brute_force() {
        prop::check(40, |rng: &mut Pcg32| {
            let mut s = state();
            let mut snap = Snapshot::with_index(SnapshotMode::Incremental, true);
            snap.refresh(&s);
            let mut live: Vec<u64> = Vec::new();
            let mut next = 1u64;
            for step in 0..rng.range_inclusive(1, 40) {
                match rng.below(4) {
                    0 | 1 => {
                        let node = NodeId(rng.below(16) as u32);
                        let want = rng.range_inclusive(1, 4) as usize;
                        let free = s.node(node).free_gpu_indices();
                        if free.len() >= want && s.node(node).health.schedulable() {
                            s.commit_placements(
                                JobId(next),
                                vec![placement(next, node.0, free[..want].to_vec())],
                            )
                            .unwrap();
                            live.push(next);
                            next += 1;
                        }
                    }
                    2 => {
                        if let Some(i) = (!live.is_empty())
                            .then(|| rng.below(live.len() as u64) as usize)
                        {
                            let j = live.swap_remove(i);
                            s.release_job(JobId(j)).unwrap();
                        }
                    }
                    _ => {
                        let node = NodeId(rng.below(16) as u32);
                        if s.node(node).allocated_gpus() == 0 {
                            let h = if s.node(node).health.schedulable() {
                                Health::Cordoned
                            } else {
                                Health::Healthy
                            };
                            s.set_node_health(node, h);
                        }
                    }
                }
                if rng.chance(0.4) || step == 0 {
                    snap.refresh(&s);
                    let ix = snap.index().unwrap();
                    for g in 0..4u32 {
                        let min = rng.below(9) as u32;
                        let max = min + rng.below(9) as u32;
                        let zones = [ZoneQuery::Any, ZoneQuery::ZoneOnly, ZoneQuery::GeneralOnly];
                        for zone in zones {
                            let got = query(ix, GroupId(g), min, max, zone);
                            let want = brute(&snap, GroupId(g), min, max, zone);
                            prop_assert!(
                                got == want,
                                "index diverged at step {step} (group {g}, \
                                 free {min}..={max}, {zone:?}): {got:?} vs {want:?}"
                            );
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
