//! `ClusterState`: the authoritative, mutable view of the cluster —
//! nodes + fabric + pools + the allocation index — with maintained
//! aggregates (per-group / per-pool / per-HBD free counts) and a mutation
//! log that feeds incremental snapshots (§3.4.3).

use std::collections::BTreeMap;
use std::fmt;

use super::gpu::{GpuType, Health};
use super::ids::{GpuTypeId, GroupId, HbdId, JobId, NodeId, PodId, PoolId};
use super::node::{AllocError, Node};
use super::pool::PoolSet;
use super::topology::Fabric;

/// One pod's physical placement.
#[derive(Debug, Clone, PartialEq)]
pub struct PodPlacement {
    pub pod: PodId,
    pub node: NodeId,
    /// Exact GPU device indices on the node.
    pub devices: Vec<u8>,
    /// The RDMA NIC paired with the pod (index on the node).
    pub nic: u8,
}

/// Errors from state mutations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StateError {
    AlreadyPlaced(JobId),
    NotPlaced(JobId),
    Alloc(AllocError),
}

impl fmt::Display for StateError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StateError::AlreadyPlaced(j) => write!(f, "job {j} already placed"),
            StateError::NotPlaced(j) => write!(f, "job {j} has no placement"),
            StateError::Alloc(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for StateError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        // `Alloc` is transparent: Display already forwards the inner
        // message, so forward the inner error's source (not the inner
        // error itself) to avoid double-rendering in error chains.
        match self {
            StateError::Alloc(e) => e.source(),
            _ => None,
        }
    }
}

impl From<AllocError> for StateError {
    fn from(e: AllocError) -> StateError {
        StateError::Alloc(e)
    }
}

/// The authoritative cluster state.
#[derive(Debug, Clone)]
pub struct ClusterState {
    pub gpu_types: Vec<GpuType>,
    pub nodes: Vec<Node>,
    pub fabric: Fabric,
    pub pools: PoolSet,
    node_pool: Vec<PoolId>,

    // Maintained aggregates.
    group_free: Vec<u32>,
    group_total: Vec<u32>,
    pool_free: Vec<u32>,
    hbd_free: Vec<u32>,
    total_gpus: u32,
    allocated_gpus: u32,

    // Allocation index.
    // BTreeMap for defence in depth: the index is point-lookup-only
    // today, but any future traversal must come out in stable id order.
    placements: BTreeMap<JobId, Vec<PodPlacement>>,

    // Mutation log for incremental snapshots: monotonically growing list of
    // touched node ids; `log_base` is the absolute offset of entry 0 so the
    // log can be compacted without invalidating consumer cursors.
    mutation_log: Vec<NodeId>,
    log_base: u64,
}

impl ClusterState {
    /// Assemble a state from parts (normally via `cluster::builder`).
    pub fn new(gpu_types: Vec<GpuType>, nodes: Vec<Node>, fabric: Fabric) -> ClusterState {
        let mut pools = PoolSet::new();
        let mut node_pool = Vec::with_capacity(nodes.len());
        for n in &nodes {
            let pool = pools.pool_for_type_mut(n.gpu_type);
            pool.add_node(n.id, n.total_gpus());
            node_pool.push(pool.id);
        }
        let num_groups = fabric.num_groups();
        let mut s = ClusterState {
            group_free: vec![0; num_groups],
            group_total: vec![0; num_groups],
            pool_free: vec![0; pools.len()],
            hbd_free: vec![0; fabric.hbds.len()],
            total_gpus: 0,
            allocated_gpus: 0,
            placements: BTreeMap::new(),
            mutation_log: Vec::new(),
            log_base: 0,
            node_pool,
            gpu_types,
            nodes,
            fabric,
            pools,
        };
        s.rebuild_aggregates();
        s
    }

    /// Recompute every aggregate from scratch (startup or after bulk edits).
    pub fn rebuild_aggregates(&mut self) {
        self.group_free.iter_mut().for_each(|x| *x = 0);
        self.group_total.iter_mut().for_each(|x| *x = 0);
        self.pool_free.iter_mut().for_each(|x| *x = 0);
        self.hbd_free.iter_mut().for_each(|x| *x = 0);
        self.total_gpus = 0;
        self.allocated_gpus = 0;
        for n in &self.nodes {
            let free = n.free_gpus();
            let g = n.group.index();
            self.group_free[g] += free;
            self.group_total[g] += n.total_gpus();
            self.pool_free[self.node_pool[n.id.index()].index()] += free;
            if let Some(h) = n.hbd {
                self.hbd_free[h.index()] += free;
            }
            self.total_gpus += n.total_gpus();
            self.allocated_gpus += n.allocated_gpus();
        }
    }

    #[inline]
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.index()]
    }

    #[inline]
    pub fn gpu_type(&self, id: GpuTypeId) -> &GpuType {
        &self.gpu_types[id.index()]
    }

    #[inline]
    pub fn pool_of_node(&self, id: NodeId) -> PoolId {
        self.node_pool[id.index()]
    }

    #[inline]
    pub fn group_free(&self, g: GroupId) -> u32 {
        self.group_free[g.index()]
    }

    #[inline]
    pub fn group_total(&self, g: GroupId) -> u32 {
        self.group_total[g.index()]
    }

    #[inline]
    pub fn hbd_free(&self, h: HbdId) -> u32 {
        self.hbd_free[h.index()]
    }

    /// Groups containing each pool's nodes (pool index → sorted, deduped
    /// group list). Static topology, derived on demand — the per-pool
    /// group walk both RSCH construction and defrag rounds rely on.
    pub fn pool_groups(&self) -> Vec<Vec<GroupId>> {
        let mut pg: Vec<Vec<GroupId>> = vec![Vec::new(); self.pools.len()];
        for pool in self.pools.iter() {
            let mut gs: Vec<GroupId> = pool
                .nodes
                .iter()
                .map(|&n| self.node(n).group)
                .collect();
            gs.sort_unstable();
            gs.dedup();
            pg[pool.id.index()] = gs;
        }
        pg
    }

    /// Free GPUs in the pool serving `gpu_type` (dynamic-admission input).
    pub fn pool_free_for_type(&self, gpu_type: GpuTypeId) -> u32 {
        self.pools
            .pool_for_type(gpu_type)
            .map(|p| self.pool_free[p.id.index()])
            .unwrap_or(0)
    }

    #[inline]
    pub fn total_gpus(&self) -> u32 {
        self.total_gpus
    }

    #[inline]
    pub fn allocated_gpus(&self) -> u32 {
        self.allocated_gpus
    }

    /// GAR numerator/denominator at this instant (§4.1).
    pub fn gpu_allocation_ratio(&self) -> f64 {
        if self.total_gpus == 0 {
            0.0
        } else {
            self.allocated_gpus as f64 / self.total_gpus as f64
        }
    }

    /// GFR (§4.3): fragmented / schedulable nodes, optionally per pool.
    pub fn fragmentation_ratio(&self, pool: Option<PoolId>) -> f64 {
        let mut fragmented = 0usize;
        let mut schedulable = 0usize;
        for n in &self.nodes {
            if let Some(p) = pool {
                if self.node_pool[n.id.index()] != p {
                    continue;
                }
            }
            if !n.health.schedulable() {
                continue;
            }
            schedulable += 1;
            if n.is_fragmented() {
                fragmented += 1;
            }
        }
        if schedulable == 0 {
            0.0
        } else {
            fragmented as f64 / schedulable as f64
        }
    }

    /// Commit a whole job's placement plan transactionally: either every
    /// pod binds or nothing does (gang semantics are enforced one level up;
    /// this guards against placement-plan races).
    pub fn commit_placements(
        &mut self,
        job: JobId,
        plan: Vec<PodPlacement>,
    ) -> Result<(), StateError> {
        if self.placements.contains_key(&job) {
            return Err(StateError::AlreadyPlaced(job));
        }
        // Validate first (no mutation).
        for p in &plan {
            let node = &self.nodes[p.node.index()];
            if !node.health.schedulable() {
                return Err(AllocError::NodeUnhealthy(p.node).into());
            }
            for &d in &p.devices {
                match node.gpus.get(d as usize) {
                    None => return Err(AllocError::NoSuchDevice(p.node, d).into()),
                    Some(g) if !g.free() => {
                        return Err(AllocError::DeviceBusy(p.node, d).into())
                    }
                    Some(_) => {}
                }
            }
        }
        // Detect intra-plan duplicate device use (two pods, same device).
        {
            let mut seen: Vec<(NodeId, u8)> = plan
                .iter()
                .flat_map(|p| p.devices.iter().map(|&d| (p.node, d)))
                .collect();
            let before = seen.len();
            seen.sort_unstable();
            seen.dedup();
            if seen.len() != before {
                // Find one offender for the error message.
                for p in &plan {
                    for &d in &p.devices {
                        if plan
                            .iter()
                            .flat_map(|q| q.devices.iter().map(move |&e| (q.node, e, q.pod)))
                            .filter(|&(n, e, _)| n == p.node && e == d)
                            .count()
                            > 1
                        {
                            return Err(AllocError::DeviceBusy(p.node, d).into());
                        }
                    }
                }
            }
        }
        // Apply.
        for p in &plan {
            self.nodes[p.node.index()]
                .allocate(p.pod, &p.devices)
                .expect("validated above");
            self.note_alloc_delta(p.node, p.devices.len() as u32, true);
        }
        self.placements.insert(job, plan);
        Ok(())
    }

    /// Release every pod of `job`; returns the placements that were freed.
    pub fn release_job(&mut self, job: JobId) -> Result<Vec<PodPlacement>, StateError> {
        let plan = self
            .placements
            .remove(&job)
            .ok_or(StateError::NotPlaced(job))?;
        for p in &plan {
            let freed = self.nodes[p.node.index()].release_pod(p.pod);
            debug_assert_eq!(freed as usize, p.devices.len());
            self.note_alloc_delta(p.node, freed, false);
        }
        Ok(plan)
    }

    fn note_alloc_delta(&mut self, node: NodeId, gpus: u32, alloc: bool) {
        let n = &self.nodes[node.index()];
        // Free-count aggregates mirror `Node::free_gpus`, which reports 0
        // for unschedulable nodes — a release on a Draining/Repairing node
        // (a resident finishing mid-drain) must not re-add capacity the
        // aggregates never counted. Allocations only land on schedulable
        // nodes (`commit_placements` validates), so they always track.
        let track_free = n.health.schedulable();
        let g = n.group.index();
        let p = self.node_pool[node.index()].index();
        let hbd = n.hbd;
        if alloc {
            debug_assert!(track_free, "allocation on unschedulable node");
            self.allocated_gpus += gpus;
            if track_free {
                self.group_free[g] -= gpus;
                self.pool_free[p] -= gpus;
                if let Some(h) = hbd {
                    self.hbd_free[h.index()] -= gpus;
                }
            }
        } else {
            self.allocated_gpus -= gpus;
            if track_free {
                self.group_free[g] += gpus;
                self.pool_free[p] += gpus;
                if let Some(h) = hbd {
                    self.hbd_free[h.index()] += gpus;
                }
            }
        }
        self.log_touch(node);
    }

    /// Apply a node's free-GPU-count change to the group/pool/HBD
    /// aggregates and record the touch in the mutation log.
    fn apply_free_delta(&mut self, node: NodeId, old_free: u32, new_free: u32) {
        let n = &self.nodes[node.index()];
        let g = n.group.index();
        let p = self.node_pool[node.index()].index();
        let hbd = n.hbd;
        if new_free >= old_free {
            let d = new_free - old_free;
            self.group_free[g] += d;
            self.pool_free[p] += d;
            if let Some(h) = hbd {
                self.hbd_free[h.index()] += d;
            }
        } else {
            let d = old_free - new_free;
            self.group_free[g] -= d;
            self.pool_free[p] -= d;
            if let Some(h) = hbd {
                self.hbd_free[h.index()] -= d;
            }
        }
        self.log_touch(node);
    }

    /// Change a node's health; aggregates update (free counts depend on
    /// schedulability) and the mutation log records the touch.
    pub fn set_node_health(&mut self, node: NodeId, health: Health) {
        let old_free = self.nodes[node.index()].free_gpus();
        self.nodes[node.index()].health = health;
        let new_free = self.nodes[node.index()].free_gpus();
        self.apply_free_delta(node, old_free, new_free);
    }

    /// Change one GPU device's health (device-level fault injection).
    /// The node's free aggregates follow — a faulted device leaves the
    /// free count — and the mutation log records the touch so the next
    /// snapshot refresh re-slots the node in the index.
    pub fn set_gpu_health(&mut self, node: NodeId, device: u8, health: Health) {
        let old_free = self.nodes[node.index()].free_gpus();
        if let Some(g) = self.nodes[node.index()].gpus.get_mut(device as usize) {
            g.health = health;
        }
        let new_free = self.nodes[node.index()].free_gpus();
        self.apply_free_delta(node, old_free, new_free);
    }

    pub fn placements_of(&self, job: JobId) -> Option<&[PodPlacement]> {
        self.placements.get(&job).map(|v| v.as_slice())
    }

    /// Nodes a job occupies (sorted, deduped).
    pub fn nodes_of(&self, job: JobId) -> Vec<NodeId> {
        let mut ns: Vec<NodeId> = self
            .placements
            .get(&job)
            .map(|v| v.iter().map(|p| p.node).collect())
            .unwrap_or_default();
        ns.sort_unstable();
        ns.dedup();
        ns
    }

    pub fn num_running_jobs(&self) -> usize {
        self.placements.len()
    }

    // ---- Mutation log (incremental snapshot feed) ----

    fn log_touch(&mut self, node: NodeId) {
        // NB: no consecutive-dedup here — a consumer whose cursor already
        // passed the previous entry would lose the new touch. Consumers
        // dedup on read; `compact_log` bounds growth.
        self.mutation_log.push(node);
    }

    /// Absolute position just past the newest log entry.
    pub fn log_head(&self) -> u64 {
        self.log_base + self.mutation_log.len() as u64
    }

    /// Entries in [from, head): the nodes touched since a consumer's cursor.
    /// Returns `None` if `from` pre-dates the compacted window (consumer
    /// must fall back to a full rebuild).
    pub fn log_since(&self, from: u64) -> Option<&[NodeId]> {
        if from < self.log_base {
            return None;
        }
        let start = (from - self.log_base) as usize;
        Some(&self.mutation_log[start.min(self.mutation_log.len())..])
    }

    /// Drop log entries older than `upto` (min cursor across consumers).
    pub fn compact_log(&mut self, upto: u64) {
        if upto <= self.log_base {
            return;
        }
        let drop = ((upto - self.log_base) as usize).min(self.mutation_log.len());
        self.mutation_log.drain(..drop);
        self.log_base += drop as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::builder::{ClusterBuilder, ClusterSpec};

    fn small_state() -> ClusterState {
        // 2 spines x 2 groups x 4 nodes x 8 GPUs = 128 GPUs.
        ClusterBuilder::build(&ClusterSpec::homogeneous("t", 2, 2, 4))
    }

    fn pod(j: u64, r: u32) -> PodId {
        PodId::new(JobId(j), r)
    }

    fn place(job: u64, node: u32, devices: Vec<u8>) -> PodPlacement {
        PodPlacement {
            pod: pod(job, 0),
            node: NodeId(node),
            devices,
            nic: 0,
        }
    }

    #[test]
    fn aggregates_track_commits_and_releases() {
        let mut s = small_state();
        assert_eq!(s.total_gpus(), 128);
        assert_eq!(s.allocated_gpus(), 0);
        let g0 = s.node(NodeId(0)).group;
        let before = s.group_free(g0);
        s.commit_placements(JobId(1), vec![place(1, 0, vec![0, 1, 2, 3])])
            .unwrap();
        assert_eq!(s.allocated_gpus(), 4);
        assert_eq!(s.group_free(g0), before - 4);
        assert!((s.gpu_allocation_ratio() - 4.0 / 128.0).abs() < 1e-12);
        s.release_job(JobId(1)).unwrap();
        assert_eq!(s.allocated_gpus(), 0);
        assert_eq!(s.group_free(g0), before);
    }

    #[test]
    fn commit_is_transactional_on_busy_device() {
        let mut s = small_state();
        s.commit_placements(JobId(1), vec![place(1, 0, vec![0])])
            .unwrap();
        let plan = vec![
            PodPlacement {
                pod: pod(2, 0),
                node: NodeId(1),
                devices: vec![0, 1],
                nic: 0,
            },
            PodPlacement {
                pod: pod(2, 1),
                node: NodeId(0),
                devices: vec![0], // Busy.
                nic: 0,
            },
        ];
        assert!(s.commit_placements(JobId(2), plan).is_err());
        // Pod 2/0's devices must not be bound.
        assert_eq!(s.node(NodeId(1)).free_gpus(), 8);
        assert_eq!(s.allocated_gpus(), 1);
    }

    #[test]
    fn commit_rejects_intra_plan_duplicates() {
        let mut s = small_state();
        let plan = vec![
            PodPlacement {
                pod: pod(1, 0),
                node: NodeId(0),
                devices: vec![0],
                nic: 0,
            },
            PodPlacement {
                pod: pod(1, 1),
                node: NodeId(0),
                devices: vec![0], // Same device!
                nic: 0,
            },
        ];
        assert!(s.commit_placements(JobId(1), plan).is_err());
        assert_eq!(s.allocated_gpus(), 0);
    }

    #[test]
    fn double_commit_rejected() {
        let mut s = small_state();
        s.commit_placements(JobId(1), vec![place(1, 0, vec![0])])
            .unwrap();
        assert!(matches!(
            s.commit_placements(JobId(1), vec![place(1, 1, vec![0])]),
            Err(StateError::AlreadyPlaced(_))
        ));
    }

    #[test]
    fn health_changes_update_free_aggregates() {
        let mut s = small_state();
        let g0 = s.node(NodeId(0)).group;
        let before = s.group_free(g0);
        s.set_node_health(NodeId(0), Health::Cordoned);
        assert_eq!(s.group_free(g0), before - 8);
        assert_eq!(s.pool_free_for_type(GpuTypeId(0)), 120);
        s.set_node_health(NodeId(0), Health::Healthy);
        assert_eq!(s.group_free(g0), before);
    }

    #[test]
    fn release_on_draining_node_keeps_aggregates_consistent() {
        // A resident finishing while its node drains must not re-add
        // free capacity the aggregates stopped counting at drain time.
        let mut s = small_state();
        let g0 = s.node(NodeId(0)).group;
        let before = s.group_free(g0);
        s.commit_placements(JobId(1), vec![place(1, 0, vec![0, 1, 2])])
            .unwrap();
        s.set_node_health(NodeId(0), Health::Draining);
        assert_eq!(s.group_free(g0), before - 8); // Whole node left the pool.
        s.release_job(JobId(1)).unwrap();
        assert_eq!(s.group_free(g0), before - 8, "release must not leak free count");
        assert_eq!(s.allocated_gpus(), 0);
        s.set_node_health(NodeId(0), Health::Healthy);
        assert_eq!(s.group_free(g0), before);
        // Aggregates agree with a from-scratch recount.
        let sum: u32 = s.nodes.iter().map(|n| n.free_gpus()).sum();
        assert_eq!(sum, 128);
    }

    #[test]
    fn gpu_health_changes_update_free_aggregates() {
        let mut s = small_state();
        let g0 = s.node(NodeId(0)).group;
        let before = s.group_free(g0);
        s.set_gpu_health(NodeId(0), 3, Health::Faulty);
        assert_eq!(s.group_free(g0), before - 1);
        assert_eq!(s.node(NodeId(0)).free_gpus(), 7);
        // Repairing → Healthy restores the device.
        s.set_gpu_health(NodeId(0), 3, Health::Healthy);
        assert_eq!(s.group_free(g0), before);
        // A device fault on an unschedulable node is a free-count no-op.
        s.set_node_health(NodeId(1), Health::Repairing);
        let mid = s.group_free(g0);
        s.set_gpu_health(NodeId(1), 0, Health::Faulty);
        assert_eq!(s.group_free(g0), mid);
    }

    #[test]
    fn fragmentation_ratio_counts_partial_nodes() {
        let mut s = small_state();
        assert_eq!(s.fragmentation_ratio(None), 0.0);
        s.commit_placements(JobId(1), vec![place(1, 0, vec![0, 1])])
            .unwrap();
        assert!((s.fragmentation_ratio(None) - 1.0 / 16.0).abs() < 1e-12);
        // A fully-allocated node is not fragmented.
        s.commit_placements(
            JobId(2),
            vec![place(2, 1, vec![0, 1, 2, 3, 4, 5, 6, 7])],
        )
        .unwrap();
        assert!((s.fragmentation_ratio(None) - 1.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn mutation_log_tracks_and_compacts() {
        let mut s = small_state();
        let head0 = s.log_head();
        s.commit_placements(JobId(1), vec![place(1, 3, vec![0])])
            .unwrap();
        s.release_job(JobId(1)).unwrap();
        let touched = s.log_since(head0).unwrap().to_vec();
        assert_eq!(touched, vec![NodeId(3), NodeId(3)]); // One per mutation.
        let head1 = s.log_head();
        s.compact_log(head1);
        assert!(s.log_since(head0).is_none()); // Pre-window cursor.
        assert_eq!(s.log_since(head1).unwrap().len(), 0);
    }

    #[test]
    fn nodes_of_reports_sorted_unique() {
        let mut s = small_state();
        let plan = vec![
            PodPlacement {
                pod: pod(1, 0),
                node: NodeId(2),
                devices: vec![0, 1],
                nic: 0,
            },
            PodPlacement {
                pod: pod(1, 1),
                node: NodeId(1),
                devices: vec![0, 1],
                nic: 0,
            },
            PodPlacement {
                pod: pod(1, 2),
                node: NodeId(2),
                devices: vec![2, 3],
                nic: 1,
            },
        ];
        s.commit_placements(JobId(1), plan).unwrap();
        assert_eq!(s.nodes_of(JobId(1)), vec![NodeId(1), NodeId(2)]);
    }
}
