//! Line-oriented source scanner: the three source-level determinism
//! rules (`ordered-iteration`, `wall-clock`, `ambient-nondeterminism`)
//! plus the allow-annotation bookkeeping they share.
//!
//! The scanner is deliberately simple — stripped lines and hand-rolled
//! token matching, no parser dependency — but it is string- and
//! comment-aware (so this module's own pattern tables never self-flag),
//! records struct fields per struct, and resolves `self.field`
//! receivers against the enclosing `impl` block. That scoping is what
//! tells `Fabric.spines` (a `Vec`, iteration fine) apart from
//! `GangFootprint`'s hash sets in the same file.

use std::collections::{BTreeMap, BTreeSet};

use super::{Finding, RULE_AMBIENT, RULE_ANNOTATION, RULE_ORDERED, RULE_WALLCLOCK};

/// Modules whose code feeds the run digest: iteration order there is
/// observable, so hash-container iteration is banned.
pub(crate) const DIGEST_MODULES: &[&str] = &["cluster/", "qsch/", "rsch/", "sim/", "job/"];

/// Files allowed to read wall clocks: the digest-inert observability
/// plane, the bench harness, and the CLI shell.
pub(crate) const WALLCLOCK_SANCTUARIES: &[&str] = &["obs/", "util/benchkit.rs", "main.rs"];

/// Hash-container methods that expose iteration order.
const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
];

/// Same-line sinks that make an unordered traversal order-insensitive.
const COMMUTATIVE_SINKS: &[&str] = &[
    ".count()",
    ".sum()",
    ".sum::<",
    ".any(",
    ".all(",
    ".min()",
    ".max()",
    ".is_empty()",
    ".len()",
];

const WALLCLOCK_TOKENS: &[&str] = &["Instant::now", "SystemTime"];

/// Ambient-nondeterminism tokens banned everywhere in `src/`; RNG must
/// come from the seeded `util::rng` generators instead.
const AMBIENT_TOKENS: &[&str] = &[
    "thread::current",
    "thread_rng",
    "from_entropy",
    "OsRng",
    "RandomState",
    "DefaultHasher",
];

/// Strips comments and string/char literals from source lines, keeping
/// state across lines (block comments, multi-line and raw strings).
/// Stripped regions collapse to a single space so tokens never fuse.
pub(crate) struct Stripper {
    state: State,
}

enum State {
    Code,
    Block(u32),
    Str,
    RawStr(usize),
}

impl Stripper {
    pub(crate) fn new() -> Stripper {
        Stripper { state: State::Code }
    }

    pub(crate) fn strip(&mut self, line: &str) -> String {
        let b = line.as_bytes();
        let mut out: Vec<u8> = Vec::with_capacity(b.len());
        let mut i = 0;
        while i < b.len() {
            match self.state {
                State::Block(depth) => {
                    if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                        i += 2;
                        if depth == 1 {
                            self.state = State::Code;
                            out.push(b' ');
                        } else {
                            self.state = State::Block(depth - 1);
                        }
                    } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                        self.state = State::Block(depth + 1);
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                State::Str => {
                    if b[i] == b'\\' {
                        i += 2;
                    } else if b[i] == b'"' {
                        self.state = State::Code;
                        out.push(b' ');
                        i += 1;
                    } else {
                        i += 1;
                    }
                }
                State::RawStr(hashes) => {
                    if b[i] == b'"' {
                        let mut n = 0;
                        while n < hashes && b.get(i + 1 + n) == Some(&b'#') {
                            n += 1;
                        }
                        if n == hashes {
                            self.state = State::Code;
                            out.push(b' ');
                            i += 1 + n;
                        } else {
                            i += 1;
                        }
                    } else {
                        i += 1;
                    }
                }
                State::Code => {
                    let c = b[i];
                    if c == b'/' && b.get(i + 1) == Some(&b'/') {
                        break; // line comment: drop the rest
                    } else if c == b'/' && b.get(i + 1) == Some(&b'*') {
                        self.state = State::Block(1);
                        i += 2;
                    } else if c == b'"' {
                        self.state = State::Str;
                        i += 1;
                    } else if (c == b'r' || c == b'b') && !prev_is_ident(b, i) {
                        match raw_string_open(b, i) {
                            Some((skip, Some(hashes))) => {
                                self.state = State::RawStr(hashes);
                                i += skip;
                            }
                            Some((skip, None)) => {
                                self.state = State::Str;
                                i += skip;
                            }
                            None => {
                                out.push(c);
                                i += 1;
                            }
                        }
                    } else if c == b'\'' {
                        // Char literal vs lifetime.
                        if b.get(i + 1) == Some(&b'\\') {
                            let close = b[i + 2..].iter().position(|&x| x == b'\'');
                            i = close.map(|p| i + 3 + p).unwrap_or(b.len());
                            out.push(b' ');
                        } else if b.get(i + 2) == Some(&b'\'') {
                            i += 3;
                            out.push(b' ');
                        } else {
                            out.push(c); // lifetime, keep
                            i += 1;
                        }
                    } else {
                        out.push(c);
                        i += 1;
                    }
                }
            }
        }
        String::from_utf8_lossy(&out).into_owned()
    }
}

fn prev_is_ident(b: &[u8], i: usize) -> bool {
    i > 0 && (b[i - 1].is_ascii_alphanumeric() || b[i - 1] == b'_')
}

/// If `b[i..]` opens a raw/byte string (`r"`, `r#"`, `b"`, `br#"` …),
/// return how many bytes the opener spans and `Some(hashes)` for raw
/// forms (`None` = plain byte string, escapes apply).
fn raw_string_open(b: &[u8], i: usize) -> Option<(usize, Option<usize>)> {
    let mut j = i + 1;
    let mut raw = b[i] == b'r';
    if b[i] == b'b' {
        if b.get(j) == Some(&b'r') {
            raw = true;
            j += 1;
        } else if b.get(j) == Some(&b'"') {
            return Some((j + 1 - i, None));
        } else {
            return None;
        }
    }
    if !raw {
        return None;
    }
    let mut hashes = 0;
    while b.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) == Some(&b'"') {
        Some((j + 1 - i, Some(hashes)))
    } else {
        None
    }
}

fn is_path_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_' || c == b'.'
}

fn is_ident_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Find `tok` in `s` at an identifier boundary (the byte before the
/// match, if any, is not an identifier byte).
fn find_boundary(s: &str, tok: &str) -> Option<usize> {
    let mut from = 0;
    while let Some(p) = s[from..].find(tok) {
        let abs = from + p;
        if !prev_is_ident(s.as_bytes(), abs) {
            return Some(abs);
        }
        from = abs + 1;
    }
    None
}

fn leading_ident(s: &str) -> &str {
    let end = s
        .bytes()
        .position(|c| !is_ident_byte(c))
        .unwrap_or(s.len());
    &s[..end]
}

// ---------------------------------------------------------------------
// Allow annotations
// ---------------------------------------------------------------------

/// One parsed allow annotation. The comment must read exactly
/// `kant-lint: allow(<rule>) — <reason>` right after its `//` marker;
/// it suppresses a finding of that rule on the same or the next line.
pub(crate) struct Allow {
    pub line: usize,
    pub rule: String,
    pub used: bool,
}

// Spelled in two pieces so the scanner does not read its own
// definition as an (always malformed) annotation.
const ANNOTATION_MARK: &str = concat!("// kant-", "lint:");

pub(crate) fn collect_allows(
    rel: &str,
    raw_lines: &[&str],
    findings: &mut Vec<Finding>,
) -> Vec<Allow> {
    let mut allows = Vec::new();
    for (idx, raw) in raw_lines.iter().enumerate() {
        let line = idx + 1;
        let Some(p) = raw.find(ANNOTATION_MARK) else {
            continue;
        };
        let rest = raw[p + ANNOTATION_MARK.len()..].trim_start();
        let bad = |findings: &mut Vec<Finding>, msg: &str| {
            findings.push(Finding {
                rule: RULE_ANNOTATION,
                file: rel.to_string(),
                line,
                what: rest.chars().take(40).collect(),
                msg: msg.to_string(),
            });
        };
        let Some(inner) = rest.strip_prefix("allow(") else {
            bad(findings, "malformed annotation: expected `allow(<rule>)`");
            continue;
        };
        let Some(close) = inner.find(')') else {
            bad(findings, "malformed annotation: missing `)`");
            continue;
        };
        let rule = &inner[..close];
        let tail = inner[close + 1..].trim();
        let reason = tail
            .trim_start_matches(['\u{2014}', '-', ' '])
            .trim();
        match rule {
            RULE_ORDERED | RULE_WALLCLOCK | RULE_AMBIENT => {
                if !(tail.starts_with('\u{2014}') || tail.starts_with('-')) || reason.is_empty() {
                    bad(
                        findings,
                        "allow annotation needs a justification: `allow(<rule>) \u{2014} <reason>`",
                    );
                } else {
                    allows.push(Allow {
                        line,
                        rule: rule.to_string(),
                        used: false,
                    });
                }
            }
            super::RULE_DIGEST => bad(
                findings,
                "digest-coverage cannot be allowed inline; list the counter in \
                 DIGEST_INERT (sim/runner.rs) with a reason instead",
            ),
            _ => bad(findings, "unknown rule in allow annotation"),
        }
    }
    allows
}

fn consume_allow(allows: &mut [Allow], line: usize, rule: &str) -> bool {
    for a in allows.iter_mut() {
        if a.rule == rule && (a.line == line || a.line + 1 == line) {
            a.used = true;
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------------
// Struct-field table (pass 1)
// ---------------------------------------------------------------------

/// Which named fields each struct in a file declares, and whether the
/// field's type is a hash container.
#[derive(Default)]
struct StructTable {
    by_struct: BTreeMap<String, BTreeMap<String, bool>>,
}

impl StructTable {
    fn field_in(&self, strukt: &str, field: &str) -> Option<bool> {
        self.by_struct.get(strukt)?.get(field).copied()
    }

    /// Unambiguous file-wide hashness of a field name: `Some(true)` only
    /// when at least one struct declares it hash-typed and none declares
    /// it otherwise.
    fn field_global(&self, field: &str) -> Option<bool> {
        let mut hash = false;
        let mut other = false;
        for fields in self.by_struct.values() {
            match fields.get(field) {
                Some(true) => hash = true,
                Some(false) => other = true,
                None => {}
            }
        }
        match (hash, other) {
            (true, false) => Some(true),
            (false, false) => None,
            _ => Some(false),
        }
    }
}

fn is_hash_type(ty: &str) -> bool {
    ty.contains("HashMap<") || ty.contains("HashSet<") || ty.contains("HashMap::")
        || ty.contains("HashSet::")
}

fn strip_visibility(t: &str) -> &str {
    for pre in ["pub(crate) ", "pub(super) ", "pub "] {
        if let Some(rest) = t.strip_prefix(pre) {
            return rest;
        }
    }
    t
}

fn struct_decl(t: &str) -> Option<String> {
    let rest = strip_visibility(t).strip_prefix("struct ")?;
    let name = leading_ident(rest);
    if name.is_empty() {
        None
    } else {
        Some(name.to_string())
    }
}

fn field_decl(t: &str) -> Option<(String, bool)> {
    let rest = strip_visibility(t);
    let name = leading_ident(rest);
    if name.is_empty() || !name.chars().next().is_some_and(|c| c.is_lowercase() || c == '_') {
        return None;
    }
    let after = rest[name.len()..].trim_start();
    let ty = after.strip_prefix(':')?;
    if ty.starts_with(':') {
        return None; // `::` path, not a field
    }
    Some((name.to_string(), is_hash_type(ty)))
}

fn collect_structs(stripped: &[String]) -> StructTable {
    let mut table = StructTable::default();
    let mut depth: i32 = 0;
    let mut cur: Option<(String, i32)> = None;
    for line in stripped {
        let t = line.trim();
        if let Some((name, d0)) = cur.clone() {
            if depth == d0 + 1 && !t.starts_with("#[") {
                if let Some((field, hash)) = field_decl(t) {
                    table
                        .by_struct
                        .entry(name.clone())
                        .or_default()
                        .insert(field, hash);
                }
            }
        } else if let Some(name) = struct_decl(t) {
            if t.contains('{') {
                cur = Some((name.clone(), depth));
                table.by_struct.entry(name).or_default();
            }
        }
        depth += brace_delta(line);
        if let Some((_, d0)) = &cur {
            if depth <= *d0 {
                cur = None;
            }
        }
    }
    table
}

fn brace_delta(line: &str) -> i32 {
    let mut d = 0;
    for b in line.bytes() {
        match b {
            b'{' => d += 1,
            b'}' => d -= 1,
            _ => {}
        }
    }
    d
}

// ---------------------------------------------------------------------
// Main scan (pass 2)
// ---------------------------------------------------------------------

fn impl_target(t: &str) -> Option<String> {
    let rest = t.strip_prefix("impl")?;
    if !rest.starts_with([' ', '<']) {
        return None;
    }
    let mut rest = rest.trim_start();
    if rest.starts_with('<') {
        let mut depth = 0i32;
        let mut end = rest.len();
        for (i, c) in rest.char_indices() {
            match c {
                '<' => depth += 1,
                '>' => {
                    depth -= 1;
                    if depth == 0 {
                        end = i + 1;
                        break;
                    }
                }
                _ => {}
            }
        }
        rest = rest[end..].trim_start();
    }
    if let Some(p) = rest.find(" for ") {
        rest = rest[p + 5..].trim_start();
    }
    let end = rest
        .find(|c: char| c == '<' || c == ' ' || c == '{')
        .unwrap_or(rest.len());
    let name = rest[..end].rsplit("::").next().unwrap_or("");
    if name.is_empty() {
        None
    } else {
        Some(name.to_string())
    }
}

/// Record hash-typed params from a fn-signature line into `locals`.
fn harvest_params(line: &str, locals: &mut BTreeSet<String>) {
    for pat in ["HashMap<", "HashSet<"] {
        let mut from = 0;
        while let Some(p) = line[from..].find(pat) {
            let abs = from + p;
            from = abs + pat.len();
            let b = line.as_bytes();
            let mut j = abs;
            while j > 0 && (is_ident_byte(b[j - 1]) || b[j - 1] == b':') {
                j -= 1;
            }
            let mut before = line[..j].trim_end();
            if let Some(s) = before.strip_suffix("mut") {
                before = s.trim_end();
            }
            before = before.trim_end_matches('&').trim_end();
            let Some(before) = before.strip_suffix(':') else {
                continue;
            };
            if before.ends_with(':') {
                continue;
            }
            let before = before.trim_end();
            let bb = before.as_bytes();
            let mut k = before.len();
            while k > 0 && is_ident_byte(bb[k - 1]) {
                k -= 1;
            }
            let name = &before[k..];
            if !name.is_empty() && name != "self" {
                locals.insert(name.to_string());
            }
        }
    }
}

fn let_binding(line: &str) -> Option<(String, bool)> {
    let p = find_boundary(line, "let ")?;
    let rest = line[p + 4..].trim_start();
    let rest = rest.strip_prefix("mut ").unwrap_or(rest).trim_start();
    let name = leading_ident(rest);
    if name.is_empty() {
        return None;
    }
    Some((name.to_string(), is_hash_type(line)))
}

pub(crate) struct SourceScan<'a> {
    rel: &'a str,
    digest_mod: bool,
    wallclock_ok: bool,
    table: StructTable,
}

impl<'a> SourceScan<'a> {
    pub(crate) fn new(rel: &'a str) -> SourceScan<'a> {
        SourceScan {
            rel,
            digest_mod: DIGEST_MODULES.iter().any(|m| rel.starts_with(m)),
            wallclock_ok: WALLCLOCK_SANCTUARIES
                .iter()
                .any(|m| rel.starts_with(m) || rel == *m),
            table: StructTable::default(),
        }
    }

    /// Scan one file's text. Returns the number of allow annotations
    /// that actually suppressed a finding.
    pub(crate) fn run(mut self, text: &str, findings: &mut Vec<Finding>) -> usize {
        let raw_lines: Vec<&str> = text.lines().collect();
        let mut allows = collect_allows(self.rel, &raw_lines, findings);

        let mut stripper = Stripper::new();
        let stripped: Vec<String> = raw_lines.iter().map(|l| stripper.strip(l)).collect();
        self.table = collect_structs(&stripped);

        let mut depth: i32 = 0;
        let mut impls: Vec<(String, i32, bool)> = Vec::new(); // (struct, depth, body open)
        let mut locals: BTreeSet<String> = BTreeSet::new();
        let mut sig = false;
        let mut skip_until: Option<i32> = None;
        let mut pending_cfg_test = false;
        let mut prev_tail = String::new();

        for (idx, line) in stripped.iter().enumerate() {
            let line_no = idx + 1;
            let t = line.trim();

            if let Some(d0) = skip_until {
                depth += brace_delta(line);
                if depth <= d0 {
                    skip_until = None;
                }
                continue;
            }
            if t.contains("#[cfg(test)]") {
                pending_cfg_test = true;
                continue;
            }
            if pending_cfg_test {
                if t.starts_with("mod ") || t.starts_with("pub mod ") {
                    let d0 = depth;
                    depth += brace_delta(line);
                    if depth > d0 {
                        skip_until = Some(d0);
                    }
                    pending_cfg_test = false;
                    continue;
                }
                if !t.is_empty() && !t.starts_with("#[") {
                    pending_cfg_test = false;
                }
            }

            // --- rule checks (against the pre-update context) ---
            let impl_name = impls.last().map(|(n, _, _)| n.as_str());
            if self.digest_mod {
                self.check_iteration(
                    line,
                    &prev_tail,
                    &locals,
                    impl_name,
                    line_no,
                    &mut allows,
                    findings,
                );
            }
            if !self.wallclock_ok {
                self.check_tokens(
                    line,
                    WALLCLOCK_TOKENS,
                    RULE_WALLCLOCK,
                    "wall-clock read outside obs/, util/benchkit.rs, main.rs",
                    line_no,
                    &mut allows,
                    findings,
                );
            }
            self.check_ambient(line, line_no, &mut allows, findings);

            // --- context updates ---
            if let Some(p) = find_boundary(line, "fn ") {
                if !leading_ident(&line[p + 3..]).is_empty() {
                    locals.clear();
                    sig = true;
                }
            }
            if sig {
                harvest_params(line, &mut locals);
                if line.contains('{') {
                    sig = false;
                }
            }
            if let Some((name, hash)) = let_binding(line) {
                if hash {
                    locals.insert(name);
                } else {
                    locals.remove(&name);
                }
            }
            if t.starts_with("impl") {
                if let Some(target) = impl_target(t) {
                    impls.push((target, depth, line.contains('{')));
                }
            }
            depth += brace_delta(line);
            if let Some(last) = impls.last_mut() {
                if !last.2 && line.contains('{') {
                    last.2 = true;
                }
            }
            while matches!(impls.last(), Some((_, d0, true)) if depth <= *d0) {
                impls.pop();
            }

            if !t.is_empty() {
                let b = line.trim_end();
                let bb = b.as_bytes();
                let mut k = b.len();
                while k > 0 && is_path_byte(bb[k - 1]) {
                    k -= 1;
                }
                prev_tail = b[k..].to_string();
            }
        }

        for a in &allows {
            if !a.used {
                findings.push(Finding {
                    rule: RULE_ANNOTATION,
                    file: self.rel.to_string(),
                    line: a.line,
                    what: format!("allow({})", a.rule),
                    msg: "unused allow annotation (nothing to suppress here)".to_string(),
                });
            }
        }
        allows.iter().filter(|a| a.used).count()
    }

    fn classify(&self, path: &str, locals: &BTreeSet<String>, impl_name: Option<&str>) -> bool {
        let segs: Vec<&str> = path.split('.').filter(|s| !s.is_empty()).collect();
        match segs.as_slice() {
            [one] => locals.contains(*one),
            ["self", f] => match impl_name.and_then(|s| self.table.field_in(s, f)) {
                Some(h) => h,
                None => self.table.field_global(f) == Some(true),
            },
            [.., f] => self.table.field_global(f) == Some(true),
            [] => false,
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn check_iteration(
        &self,
        line: &str,
        prev_tail: &str,
        locals: &BTreeSet<String>,
        impl_name: Option<&str>,
        line_no: usize,
        allows: &mut [Allow],
        findings: &mut Vec<Finding>,
    ) {
        let mut emit = |what: String, findings: &mut Vec<Finding>, allows: &mut [Allow]| {
            if COMMUTATIVE_SINKS.iter().any(|s| line.contains(s)) {
                return; // provably order-insensitive on this line
            }
            if consume_allow(allows, line_no, RULE_ORDERED) {
                return;
            }
            findings.push(Finding {
                rule: RULE_ORDERED,
                file: self.rel.to_string(),
                line: line_no,
                what,
                msg: "iteration over a hash container in a digest-affecting module; \
                      use BTreeMap/BTreeSet or sorted keys, feed a commutative fold, \
                      or annotate `kant-lint: allow(ordered-iteration) \u{2014} <reason>`"
                    .to_string(),
            });
        };

        for m in ITER_METHODS {
            let pat = format!(".{m}()");
            let mut from = 0;
            while let Some(p) = line[from..].find(&pat) {
                let abs = from + p;
                from = abs + pat.len();
                let b = line.as_bytes();
                let mut j = abs;
                while j > 0 && is_path_byte(b[j - 1]) {
                    j -= 1;
                }
                let receiver = if j == abs {
                    if line[..abs].trim().is_empty() {
                        prev_tail // continuation of a wrapped method chain
                    } else {
                        continue; // e.g. a call result: not classifiable
                    }
                } else {
                    &line[j..abs]
                };
                if self.classify(receiver, locals, impl_name) {
                    emit(format!("{receiver}.{m}()"), findings, allows);
                }
            }
        }

        if let Some(fp) = find_boundary(line, "for ") {
            if let Some(ip) = line[fp..].find(" in ") {
                let after = &line[fp + ip + 4..];
                let end = after.find('{').unwrap_or(after.len());
                let mut it = after[..end].trim();
                it = it.strip_prefix('&').unwrap_or(it);
                it = it.strip_prefix("mut ").unwrap_or(it).trim();
                if !it.is_empty()
                    && it.bytes().all(is_path_byte)
                    && self.classify(it, locals, impl_name)
                {
                    emit(format!("for \u{2026} in {it}"), findings, allows);
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn check_tokens(
        &self,
        line: &str,
        tokens: &[&str],
        rule: &'static str,
        msg: &str,
        line_no: usize,
        allows: &mut [Allow],
        findings: &mut Vec<Finding>,
    ) {
        for tok in tokens {
            if find_boundary(line, tok).is_some() {
                if consume_allow(allows, line_no, rule) {
                    return;
                }
                findings.push(Finding {
                    rule,
                    file: self.rel.to_string(),
                    line: line_no,
                    what: tok.to_string(),
                    msg: msg.to_string(),
                });
                return; // one finding per line is enough
            }
        }
    }

    fn check_ambient(
        &self,
        line: &str,
        line_no: usize,
        allows: &mut [Allow],
        findings: &mut Vec<Finding>,
    ) {
        self.check_tokens(
            line,
            AMBIENT_TOKENS,
            RULE_AMBIENT,
            "ambient nondeterminism (thread identity / unseeded RNG / random hash \
             state); derive randomness from the seeded util::rng generators",
            line_no,
            allows,
            findings,
        );
        if self.digest_mod && find_boundary(line, "env::var").is_some() {
            if consume_allow(allows, line_no, RULE_AMBIENT) {
                return;
            }
            findings.push(Finding {
                rule: RULE_AMBIENT,
                file: self.rel.to_string(),
                line: line_no,
                what: "env::var".to_string(),
                msg: "environment reads inside the scheduler core make behaviour \
                      host-dependent; thread configuration through SimOptions instead"
                    .to_string(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strip_all(text: &str) -> Vec<String> {
        let mut s = Stripper::new();
        text.lines().map(|l| s.strip(l)).collect()
    }

    #[test]
    fn stripper_removes_strings_comments_and_chars() {
        let out = strip_all("let x = \"Instant::now\"; // Instant::now\nlet c = 'x';");
        assert_eq!(out[0].trim_end(), "let x =  ;");
        assert_eq!(out[1], "let c =  ;");
    }

    #[test]
    fn stripper_tracks_block_comments_and_raw_strings() {
        let out = strip_all("a /* x\ny */ b\nlet r = r#\"keys()\n.values()\"#; c");
        assert_eq!(out[0], "a ");
        assert_eq!(out[1].trim(), "b");
        assert_eq!(out[2], "let r = ");
        assert_eq!(out[3].trim(), "; c");
    }

    #[test]
    fn stripper_keeps_lifetimes() {
        let out = strip_all("fn f<'a>(x: &'a str) -> &'a str { x }");
        assert_eq!(out[0], "fn f<'a>(x: &'a str) -> &'a str { x }");
    }

    #[test]
    fn struct_table_scopes_fields_per_struct() {
        let stripped = strip_all(
            "pub struct A {\n    pub nodes: HashSet<u64>,\n}\n\
             pub struct B {\n    pub nodes: Vec<u64>,\n    map: HashMap<u64, u64>,\n}\n",
        );
        let t = collect_structs(&stripped);
        assert_eq!(t.field_in("A", "nodes"), Some(true));
        assert_eq!(t.field_in("B", "nodes"), Some(false));
        assert_eq!(t.field_global("nodes"), Some(false)); // ambiguous
        assert_eq!(t.field_global("map"), Some(true));
    }

    #[test]
    fn impl_target_handles_generics_and_traits() {
        assert_eq!(impl_target("impl Foo {"), Some("Foo".to_string()));
        assert_eq!(impl_target("impl<'a> Iterator for Bar<'a> {"), Some("Bar".to_string()));
        assert_eq!(impl_target("implicit {"), None);
    }
}
