//! The `digest-coverage` rule: every counter field on `QschStats` and
//! `RschStats` must either be read by `SimOutcome::digest_json` or be
//! listed — with a reason — in the `DIGEST_INERT` manifest next to it
//! (`sim/runner.rs`). New counters therefore cannot silently dodge the
//! determinism gate: a field in neither place is a finding, as is a
//! stale manifest entry or one that contradicts the digest body.

use super::scan::Stripper;
use super::{Finding, RULE_DIGEST};

const QSCH_FILE: &str = "qsch/mod.rs";
const RSCH_FILE: &str = "rsch/mod.rs";
const RUNNER_FILE: &str = "sim/runner.rs";

/// Run the rule over an in-memory corpus of `(rel_path, text)` files.
/// Returns how many stats fields were checked (0 when the corpus does
/// not carry the stats structs at all, e.g. source-rule fixture trees).
pub(crate) fn check(files: &[(String, String)], findings: &mut Vec<Finding>) -> usize {
    let qsch = lookup(files, QSCH_FILE);
    let rsch = lookup(files, RSCH_FILE);
    if qsch.is_none() && rsch.is_none() {
        return 0;
    }
    let Some(runner) = lookup(files, RUNNER_FILE) else {
        findings.push(finding(
            RUNNER_FILE,
            1,
            "sim/runner.rs",
            "digest-coverage cannot run: sim/runner.rs (digest_json + DIGEST_INERT) \
             is missing from the scanned tree",
        ));
        return 0;
    };

    let body = fn_body(runner, "fn digest_json");
    if body.is_empty() {
        findings.push(finding(
            RUNNER_FILE,
            1,
            "digest_json",
            "digest-coverage cannot run: no `fn digest_json` found in sim/runner.rs",
        ));
        return 0;
    }
    let inert = parse_inert(runner, findings);

    let mut checked = 0;
    let mut known: Vec<String> = Vec::new();
    for (prefix, strukt, file, text) in [
        ("qsch", "QschStats", QSCH_FILE, qsch),
        ("rsch", "RschStats", RSCH_FILE, rsch),
    ] {
        let Some(text) = text else { continue };
        let fields = struct_fields(text, strukt);
        if fields.is_empty() {
            findings.push(finding(
                file,
                1,
                strukt,
                "digest-coverage: stats struct not found or has no fields",
            ));
            continue;
        }
        for (name, line) in fields {
            checked += 1;
            let key = format!("{prefix}.{name}");
            let in_digest = body_reads(&body, prefix, &name);
            let in_manifest = inert.iter().any(|(k, _)| *k == key);
            if in_digest && in_manifest {
                let mline = inert.iter().find(|(k, _)| *k == key).map(|(_, l)| *l).unwrap_or(1);
                findings.push(finding(
                    RUNNER_FILE,
                    mline,
                    &key,
                    "digest-coverage: counter is listed in DIGEST_INERT but digest_json \
                     reads it; drop the stale manifest entry",
                ));
            } else if !in_digest && !in_manifest {
                findings.push(finding(
                    file,
                    line,
                    &key,
                    "digest-coverage: counter is neither read by digest_json nor listed \
                     in DIGEST_INERT (sim/runner.rs); cover it or declare it inert with \
                     a reason",
                ));
            }
            known.push(key);
        }
    }
    for (key, line) in &inert {
        if !known.iter().any(|k| k == key) {
            findings.push(finding(
                RUNNER_FILE,
                *line,
                key,
                "digest-coverage: DIGEST_INERT names a counter that no stats struct \
                 declares; remove the stale entry",
            ));
        }
    }
    checked
}

fn finding(file: &str, line: usize, what: &str, msg: &str) -> Finding {
    Finding {
        rule: RULE_DIGEST,
        file: file.to_string(),
        line,
        what: what.to_string(),
        msg: msg.to_string(),
    }
}

fn lookup<'a>(files: &'a [(String, String)], rel: &str) -> Option<&'a str> {
    files.iter().find(|(r, _)| r == rel).map(|(_, t)| t.as_str())
}

/// `true` when the digest body contains `<prefix>_stats.<field>` at an
/// identifier boundary (the digest reads counters as
/// `self.qsch_stats.scheduled` etc.; string keys are stripped away, so
/// a matching JSON label alone cannot fake coverage).
fn body_reads(body: &str, prefix: &str, field: &str) -> bool {
    let tok = format!("{prefix}_stats.{field}");
    let b = body.as_bytes();
    let mut from = 0;
    while let Some(p) = body[from..].find(&tok) {
        let abs = from + p;
        from = abs + tok.len();
        let before_ok = abs == 0 || !(b[abs - 1].is_ascii_alphanumeric() || b[abs - 1] == b'_');
        let end = abs + tok.len();
        let after_ok =
            end >= b.len() || !(b[end].is_ascii_alphanumeric() || b[end] == b'_');
        if before_ok && after_ok {
            return true;
        }
    }
    false
}

/// Collect the stripped body of the first `needle` fn in `text`.
fn fn_body(text: &str, needle: &str) -> String {
    let mut stripper = Stripper::new();
    let mut body = String::new();
    let mut depth = 0i32;
    let mut in_fn = false;
    let mut opened = false;
    for raw in text.lines() {
        let line = stripper.strip(raw);
        if !in_fn {
            if line.contains(needle) {
                in_fn = true;
            } else {
                continue;
            }
        }
        body.push_str(&line);
        body.push('\n');
        for b in line.bytes() {
            match b {
                b'{' => {
                    depth += 1;
                    opened = true;
                }
                b'}' => depth -= 1,
                _ => {}
            }
        }
        if opened && depth <= 0 {
            break;
        }
    }
    if opened {
        body
    } else {
        String::new()
    }
}

/// Named fields of `strukt` in `text`, with their 1-based lines.
fn struct_fields(text: &str, strukt: &str) -> Vec<(String, usize)> {
    let mut stripper = Stripper::new();
    let decl = format!("struct {strukt} {{");
    let mut fields = Vec::new();
    let mut depth = 0i32;
    let mut inside: Option<i32> = None;
    for (idx, raw) in text.lines().enumerate() {
        let line = stripper.strip(raw);
        let t = line.trim();
        if let Some(d0) = inside {
            if depth == d0 + 1 && !t.starts_with("#[") {
                if let Some(name) = field_name(t) {
                    fields.push((name, idx + 1));
                }
            }
        } else if t.contains(&decl) {
            inside = Some(depth);
        }
        for b in line.bytes() {
            match b {
                b'{' => depth += 1,
                b'}' => depth -= 1,
                _ => {}
            }
        }
        if let Some(d0) = inside {
            if depth <= d0 && t.contains('}') {
                break;
            }
        }
    }
    fields
}

fn field_name(t: &str) -> Option<String> {
    let mut rest = t;
    for pre in ["pub(crate) ", "pub(super) ", "pub "] {
        if let Some(r) = rest.strip_prefix(pre) {
            rest = r;
            break;
        }
    }
    let end = rest
        .bytes()
        .position(|c| !(c.is_ascii_alphanumeric() || c == b'_'))
        .unwrap_or(rest.len());
    let name = &rest[..end];
    if name.is_empty() || !name.starts_with(|c: char| c.is_lowercase() || c == '_') {
        return None;
    }
    let after = rest[end..].trim_start();
    if after.starts_with(':') && !after.starts_with("::") {
        Some(name.to_string())
    } else {
        None
    }
}

/// Parse `DIGEST_INERT` entries `("<group>.<field>", "<reason>")` from
/// `sim/runner.rs`, tolerating rustfmt wrapping. Empty reasons are
/// findings — the manifest's whole point is the recorded justification.
fn parse_inert(runner: &str, findings: &mut Vec<Finding>) -> Vec<(String, usize)> {
    let mut entries = Vec::new();
    let mut in_const = false;
    let mut literals: Vec<(String, usize)> = Vec::new();
    for (idx, raw) in runner.lines().enumerate() {
        if !in_const {
            if raw.contains("const DIGEST_INERT") {
                in_const = true;
            }
            continue;
        }
        for lit in string_literals(raw) {
            literals.push((lit, idx + 1));
        }
        if raw.contains("];") {
            break;
        }
    }
    if !in_const {
        findings.push(finding(
            RUNNER_FILE,
            1,
            "DIGEST_INERT",
            "digest-coverage: no `const DIGEST_INERT` manifest found in sim/runner.rs",
        ));
        return entries;
    }
    let mut it = literals.into_iter();
    while let Some((name, line)) = it.next() {
        match it.next() {
            Some((reason, _)) if !reason.trim().is_empty() => entries.push((name, line)),
            _ => findings.push(finding(
                RUNNER_FILE,
                line,
                &name,
                "digest-coverage: DIGEST_INERT entry needs a non-empty reason string",
            )),
        }
    }
    entries
}

/// Plain string literals on one raw line (no escape handling needed for
/// the manifest's simple names and reasons).
fn string_literals(raw: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = raw;
    while let Some(start) = rest.find('"') {
        let after = &rest[start + 1..];
        let Some(end) = after.find('"') else { break };
        out.push(after[..end].to_string());
        rest = &after[end + 1..];
    }
    out
}
