//! `kant lint` — the project's determinism & concurrency static
//! analysis (a zero-dependency, line-oriented scanner over `src/**`).
//!
//! Every claim the reproduction makes — golden-gate digests, `--shards
//! N` byte-identical replay, the digest-inert observability plane —
//! rests on the scheduler core being deterministic *by construction*.
//! This pass enforces that contract at the source level with four
//! rules:
//!
//! | rule | what it bans |
//! |------|--------------|
//! | `ordered-iteration` | iterating a `HashMap`/`HashSet` in a digest-affecting module (`cluster/`, `qsch/`, `rsch/`, `sim/`, `job/`) unless the traversal feeds a same-line commutative fold |
//! | `wall-clock` | `Instant::now` / `SystemTime` outside `obs/`, `util/benchkit.rs`, `main.rs` |
//! | `ambient-nondeterminism` | thread identity, unseeded RNG, random hash state, and `env::var` inside the core |
//! | `digest-coverage` | a `QschStats`/`RschStats` counter that neither `digest_json` reads nor the `DIGEST_INERT` manifest declares inert |
//!
//! A site that is genuinely order-insensitive can carry a line comment
//! of the exact form `kant-lint: allow(<rule>) — <reason>` (same line
//! or the line above); the reason is mandatory, unknown
//! rules and unused allows are themselves findings, and
//! `digest-coverage` cannot be allowed inline — the manifest is its
//! escape hatch. `kant lint --json` emits a `kant-lint-v1` document;
//! CI fails on any finding.

use std::path::Path;

use crate::util::json::Json;

mod digest;
mod scan;

pub const RULE_ORDERED: &str = "ordered-iteration";
pub const RULE_WALLCLOCK: &str = "wall-clock";
pub const RULE_AMBIENT: &str = "ambient-nondeterminism";
pub const RULE_DIGEST: &str = "digest-coverage";
/// Meta-rule: malformed / unknown / unused allow annotations.
pub const RULE_ANNOTATION: &str = "annotation";

pub const RULES: [&str; 5] = [
    RULE_ORDERED,
    RULE_WALLCLOCK,
    RULE_AMBIENT,
    RULE_DIGEST,
    RULE_ANNOTATION,
];

/// One lint finding, anchored to a `file:line` in the scanned tree.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    /// Path relative to the scanned root, `/`-separated.
    pub file: String,
    pub line: usize,
    /// The offending token / expression, e.g. `self.jobs.values()`.
    pub what: String,
    pub msg: String,
}

/// The outcome of one lint run.
#[derive(Debug, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    /// Allow annotations that suppressed a finding.
    pub allows_used: usize,
    /// Stats counters checked by the digest-coverage rule.
    pub digest_fields_checked: usize,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// The machine-readable `kant-lint-v1` document CI diffs against an
    /// empty-findings baseline (`Json::Obj` is a `BTreeMap`, so the
    /// rendering is stable).
    pub fn to_json(&self) -> Json {
        let mut counts = Json::obj();
        for rule in RULES {
            let n = self.findings.iter().filter(|f| f.rule == rule).count();
            counts.set(rule, n as u64);
        }
        let findings: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                let mut o = Json::obj();
                o.set("rule", f.rule)
                    .set("file", f.file.as_str())
                    .set("line", f.line as u64)
                    .set("what", f.what.as_str())
                    .set("msg", f.msg.as_str());
                o
            })
            .collect();
        let mut doc = Json::obj();
        doc.set("schema", "kant-lint-v1")
            .set("files_scanned", self.files_scanned as u64)
            .set("allows_used", self.allows_used as u64)
            .set("digest_fields_checked", self.digest_fields_checked as u64)
            .set("counts", counts)
            .set("findings", Json::Arr(findings));
        doc
    }

    /// GitHub Actions workflow annotations (`::error file=…`): the CI
    /// lint job prints these so findings land on the PR diff.
    pub fn github_annotations(&self, path_prefix: &str) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!(
                "::error file={}{},line={}::[{}] {}: {}\n",
                path_prefix, f.file, f.line, f.rule, f.what, f.msg
            ));
        }
        out
    }

    /// Human-readable rendering for the terminal.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!(
                "{}:{}: [{}] {}\n    {}\n",
                f.file, f.line, f.rule, f.what, f.msg
            ));
        }
        out.push_str(&format!(
            "kant lint: {} finding(s) in {} file(s); {} allow(s) used, {} digest field(s)\n",
            self.findings.len(),
            self.files_scanned,
            self.allows_used,
            self.digest_fields_checked
        ));
        out
    }
}

/// Lint an in-memory corpus of `(rel_path, text)` files. This is the
/// whole analysis — `lint_tree` is just a filesystem loader around it —
/// so the self-tests can lint fixture trees and surgically mutated
/// copies of the real sources without touching disk.
pub fn lint_corpus(files: &[(String, String)]) -> LintReport {
    let mut sorted: Vec<&(String, String)> = files.iter().collect();
    sorted.sort_by(|a, b| a.0.cmp(&b.0));
    let mut report = LintReport::default();
    for (rel, text) in sorted {
        report.allows_used += scan::SourceScan::new(rel).run(text, &mut report.findings);
        report.files_scanned += 1;
    }
    report.digest_fields_checked = digest::check(files, &mut report.findings);
    report
        .findings
        .sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    report
}

/// Lint every `.rs` file under `root` (normally `src/`).
pub fn lint_tree(root: &Path) -> std::io::Result<LintReport> {
    let mut files: Vec<(String, String)> = Vec::new();
    collect_rs(root, root, &mut files)?;
    Ok(lint_corpus(&files))
}

fn collect_rs(
    root: &Path,
    dir: &Path,
    files: &mut Vec<(String, String)>,
) -> std::io::Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let path = entry.path();
        if path.is_dir() {
            collect_rs(root, &path, files)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            files.push((rel, std::fs::read_to_string(&path)?));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus(files: &[(&str, &str)]) -> Vec<(String, String)> {
        files
            .iter()
            .map(|(r, t)| (r.to_string(), t.to_string()))
            .collect()
    }

    #[test]
    fn clean_file_yields_no_findings() {
        let r = lint_corpus(&corpus(&[(
            "qsch/mod.rs",
            "use std::collections::BTreeMap;\n\
             pub struct Q {\n    jobs: BTreeMap<u64, u64>,\n}\n\
             impl Q {\n    fn all(&self) -> Vec<u64> {\n        \
             self.jobs.values().copied().collect()\n    }\n}\n",
        )]));
        assert!(r.is_clean(), "unexpected findings: {:?}", r.findings);
        assert_eq!(r.files_scanned, 1);
    }

    #[test]
    fn hash_iteration_in_core_is_a_finding() {
        let r = lint_corpus(&corpus(&[(
            "rsch/mod.rs",
            "use std::collections::HashMap;\n\
             pub struct R {\n    cache: HashMap<u64, u64>,\n}\n\
             impl R {\n    fn all(&self) -> Vec<u64> {\n        \
             self.cache.values().copied().collect()\n    }\n}\n",
        )]));
        assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].rule, RULE_ORDERED);
        assert_eq!(r.findings[0].line, 7);
    }

    #[test]
    fn same_iteration_outside_core_is_fine() {
        let r = lint_corpus(&corpus(&[(
            "metrics/mod.rs",
            "use std::collections::HashMap;\n\
             pub struct R {\n    cache: HashMap<u64, u64>,\n}\n\
             impl R {\n    fn all(&self) -> Vec<u64> {\n        \
             self.cache.values().copied().collect()\n    }\n}\n",
        )]));
        assert!(r.is_clean(), "{:?}", r.findings);
    }

    #[test]
    fn commutative_sinks_are_exempt() {
        let r = lint_corpus(&corpus(&[(
            "cluster/x.rs",
            "use std::collections::HashSet;\n\
             fn f(seen: &HashSet<u64>) -> usize {\n    \
             seen.iter().filter(|x| **x > 3).count()\n}\n",
        )]));
        assert!(r.is_clean(), "{:?}", r.findings);
    }

    #[test]
    fn wall_clock_placement_is_policed() {
        let hit = ("sim/t.rs", "fn f() {\n    let t = std::time::Instant::now();\n}\n");
        let ok = ("obs/t.rs", "fn f() {\n    let t = std::time::Instant::now();\n}\n");
        let r = lint_corpus(&corpus(&[hit, ok]));
        assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].rule, RULE_WALLCLOCK);
        assert_eq!(r.findings[0].file, "sim/t.rs");
    }

    #[test]
    fn json_document_has_the_schema_tag() {
        let doc = LintReport::default().to_json();
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some("kant-lint-v1"));
        let text = doc.to_string_compact();
        let reparsed = Json::parse(&text).expect("round-trip");
        assert_eq!(reparsed.get("files_scanned").and_then(Json::as_u64), Some(0));
    }
}
